//! The refined per-iteration predictor (paper §6.5).
//!
//! This is the model the paper validates in Fig. 4: the leading-order
//! Table 3 costs refined with
//!
//! * **cache-aware compute** — `γ(W)` tier selected by the per-rank weight
//!   slab, so an nnz-greedy partition whose overloaded rank holds an 11 MB
//!   slab prices at DRAM speed while cyclic prices at L2;
//! * **rank-aware bandwidth** — row Allreduce priced at `β(p_c)`, column at
//!   `β(p_r)`;
//! * **load imbalance** — the slowest rank carries `κ×` the mean nonzeros;
//! * **sync-skew** — the difference between the slow rank's and the mean
//!   rank's compute time is charged to the row Allreduce as waiting time
//!   (`T_skew ≈ (κ−1)·T_compute,avg`), which is where the paper's Table 10
//!   shows poor partitioning actually bites;
//! * **per-call column floor** — an optional `c · n_local` term standing in
//!   for MKL `sparse_syrkd`'s inspector overhead (§6.5 notes the model
//!   omits it by default; we expose it as a calibration knob).
//!
//! The predictor prices *our* kernels (merge/scatter Gram, CSR SpMV), so
//! its validation target is the engine's measured per-iteration time.

use super::calib::CalibProfile;
use super::model::{DataShape, HybridConfig};
use crate::collectives::{self, AlgoPolicy, SelectorSource};
use crate::timeline::OverlapPolicy;
use crate::WORD_BYTES;

/// Shape of a concrete partition, extracted from real partition statistics.
#[derive(Clone, Copy, Debug)]
pub struct PartitionShape {
    /// Mesh-level nnz imbalance `κ = max/mean` over ranks.
    pub kappa: f64,
    /// Mean per-rank local column count (`n/p_c` for exact partitioners).
    pub n_local_mean: f64,
    /// Largest per-rank local column count (the cache-footprint driver).
    pub n_local_max: f64,
}

impl PartitionShape {
    /// Extract from a column partition.
    pub fn of(part: &crate::partition::ColPartition) -> PartitionShape {
        PartitionShape {
            kappa: part.kappa(),
            n_local_mean: part.n() as f64 / part.p_c as f64,
            n_local_max: part.max_n_local() as f64,
        }
    }
}

/// Tuning knobs of the refined predictor.
#[derive(Clone, Copy, Debug)]
pub struct PredictorKnobs {
    /// Sigmoid cost factor φ (flops per sigmoid element, > 1 for exp/div).
    pub phi: f64,
    /// Per-Gram-call column floor in seconds per local column (the
    /// `sparse_syrkd` inspector analogue; 0 = our kernels, which do not
    /// scan the column space).
    pub syrkd_floor_s_per_col: f64,
    /// Bytes streamed per stored nonzero in CSR traversal (8-byte value +
    /// 4-byte index).
    pub bytes_per_nnz: f64,
    /// Collective-algorithm policy the communication terms are priced
    /// under — `Auto` mirrors the engine's default selection, `Fixed(_)`
    /// prices a pinned algorithm (e.g. for per-algorithm sweeps).
    pub algo: AlgoPolicy,
    /// Curve family `Auto` selection prices from — mirror of the
    /// engine's [`Engine::selector`](crate::comm::Engine) knob, so the
    /// predictor's picks track a measured tuning table when the profile
    /// carries per-algorithm curves.
    pub source: SelectorSource,
    /// Overlap policy the row Allreduce is priced under — with `Bundle`,
    /// its transfer hides behind the per-iteration compute window
    /// (Gram + SpMV + weights + correction) and only the exposed
    /// remainder (plus the sync-skew wait, which is not overlappable)
    /// lands in `sstep_comm`; the hidden part is reported separately.
    pub overlap: OverlapPolicy,
}

impl Default for PredictorKnobs {
    fn default() -> Self {
        PredictorKnobs {
            phi: 12.0,
            syrkd_floor_s_per_col: 0.0,
            bytes_per_nnz: 12.0,
            algo: AlgoPolicy::Auto,
            source: SelectorSource::Analytic,
            overlap: OverlapPolicy::Off,
        }
    }
}

/// Predicted per-iteration breakdown (seconds; "iteration" = one mini-batch
/// step per row team, so an s-step bundle amortizes over `s` iterations and
/// the column sync over `τ`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PredictedIter {
    /// Gram formation (amortized per iteration).
    pub gram: f64,
    /// Row-team Allreduce: exposed Hockney transfer + sync-skew wait.
    pub sstep_comm: f64,
    /// ... of which sync-skew wait.
    pub sstep_skew: f64,
    /// Row transfer hidden behind overlapped compute (uncharged — not in
    /// [`PredictedIter::total`]; zero with overlap off).
    pub sstep_hidden: f64,
    /// Column-team Allreduce (amortized over τ).
    pub fedavg_comm: f64,
    /// Weight update.
    pub weights: f64,
    /// Forward + transpose SpMV.
    pub spgemv: f64,
    /// Dense recurrence correction + sigmoid.
    pub correction: f64,
}

impl PredictedIter {
    /// Total predicted algorithm time per iteration.
    pub fn total(&self) -> f64 {
        self.gram + self.sstep_comm + self.fedavg_comm + self.weights + self.spgemv
            + self.correction
    }
}

/// Predict the per-iteration cost of a HybridSGD configuration on a
/// partitioned dataset.
pub fn predict(
    cfg: &HybridConfig,
    data: &DataShape,
    part: &PartitionShape,
    profile: &CalibProfile,
    knobs: &PredictorKnobs,
) -> PredictedIter {
    let (s, b, tau) = (cfg.s as f64, cfg.b as f64, cfg.tau as f64);
    let p_c = cfg.mesh.p_c as f64;
    let w = WORD_BYTES as f64;

    // Mean nonzeros per local batch row: z̄ / p_c.
    let z_loc = data.zbar / p_c;
    let ws_mean = (part.n_local_mean * w) as usize;
    let ws_max = (part.n_local_max * w) as usize;

    // --- per-rank compute at the MEAN rank ------------------------------
    let t = compute_phases(s, b, z_loc, part.n_local_mean, ws_mean, 1.0, profile, knobs);
    // --- per-rank compute at the SLOWEST rank (κ× nnz, worst slab) ------
    let t_slow =
        compute_phases(s, b, z_loc * part.kappa, part.n_local_max, ws_max, 1.0, profile, knobs);

    // Sync-skew: the row Allreduce inherits the wait for the slowest rank's
    // extra compute (paper: T_skew ≈ (κ_local − 1)·T_compute,avg; we use
    // the direct slow-minus-mean form, which reduces to the paper's when
    // cache tiers are equal).
    let compute_mean = t.gram + t.spgemv + t.weights + t.correction;
    let compute_slow = t_slow.gram + t_slow.spgemv + t_slow.weights + t_slow.correction;
    let skew = (compute_slow - compute_mean).max(0.0);

    // --- communication ---------------------------------------------------
    // Row Allreduce per bundle: partial products v (s·b words) + lower-
    // triangular Gram (sb(sb+1)/2 words), across the p_c-rank row team,
    // priced by the policy-selected collective algorithm (the same
    // selection the engine charges).
    let sb = (cfg.s * cfg.b) as f64;
    let row_words = (sb + sb * (sb + 1.0) / 2.0) as usize;
    let (_, row_cost) =
        collectives::charge_with(profile, knobs.algo, knobs.source, cfg.mesh.p_c, row_words);
    let row_t = row_cost.time / s;
    // Column Allreduce per round: the n/p_c weight shard across p_r ranks.
    let col_words = part.n_local_mean as usize;
    let (_, col_cost) =
        collectives::charge_with(profile, knobs.algo, knobs.source, cfg.mesh.p_r, col_words);
    let col_t = col_cost.time / tau;

    // Overlap: the pipelined row transfer hides behind the iteration's
    // compute window; the skew wait stays exposed (a slow rank is late,
    // nothing hides behind lateness).
    let (row_exposed, row_hidden) = match knobs.overlap {
        OverlapPolicy::Off => (row_t, 0.0),
        OverlapPolicy::Bundle => {
            let window = t.gram + t.spgemv + t.weights + t.correction;
            let exposed = (row_t - window).max(0.0);
            (exposed, row_t - exposed)
        }
    };

    PredictedIter {
        gram: t.gram,
        sstep_comm: row_exposed + skew,
        sstep_skew: skew,
        sstep_hidden: row_hidden,
        fedavg_comm: col_t,
        weights: t.weights,
        spgemv: t.spgemv,
        correction: t.correction,
    }
}

struct ComputePhases {
    gram: f64,
    spgemv: f64,
    weights: f64,
    correction: f64,
}

/// Per-iteration compute phases for one rank with `z_loc` nonzeros per
/// local batch row and an `n_local`-column weight slab in tier `ws`.
#[allow(clippy::too_many_arguments)]
fn compute_phases(
    s: f64,
    b: f64,
    z_loc: f64,
    n_local: f64,
    ws: usize,
    scale: f64,
    profile: &CalibProfile,
    knobs: &PredictorKnobs,
) -> ComputePhases {
    let sb = s * b;
    let gamma_ws = profile.gamma_ws(ws);
    let gf = profile.gamma_flop;

    // Gram per bundle: scatter/gather structure — sb row scatters + cleans
    // (2·z_loc each) and C(sb,2) pair gathers (z_loc each); plus the
    // optional per-call column floor. Amortized /s per iteration.
    let pair_gathers = sb * (sb - 1.0) / 2.0;
    let gram_flops = 2.0 * sb * z_loc + pair_gathers * z_loc;
    let gram_bytes = gram_flops * knobs.bytes_per_nnz / 2.0;
    let gram =
        scale * (gram_flops * gf + gram_bytes * gamma_ws + knobs.syrkd_floor_s_per_col * n_local)
            / s;

    // SpMV per iteration: forward (2·b·z_loc flops) + transpose scatter
    // (2·b·z_loc), streaming CSR bytes plus one read pass over the local
    // weight slab (§6.5 cache-aware term — mirrors the engine's charge).
    let spmv_flops = 4.0 * b * z_loc;
    let spmv_bytes = 2.0 * b * z_loc * knobs.bytes_per_nnz + n_local * 8.0 / s;
    let spgemv = scale * (spmv_flops * gf + spmv_bytes * gamma_ws);

    // Weight update per bundle: axpy over the local slab, /s per iter.
    let weights = scale * (2.0 * n_local * gf + 2.0 * n_local * 8.0 * gamma_ws) / s;

    // Correction per bundle: C(s,2) dense b×b block products (2b² flops
    // each) + sigmoid φ·b per iteration. Replicated on every rank.
    let corr_flops = s * (s - 1.0) * b * b; // 2·C(s,2)·b²
    let correction = scale * (corr_flops * gf / s + knobs.phi * b * gf);

    ComputePhases { gram, spgemv, weights, correction }
}

/// Rank partitioner candidates by predicted per-iteration total (ascending
/// — the Fig. 4 ranking-fidelity target).
pub fn rank_partitioners(
    cfg: &HybridConfig,
    data: &DataShape,
    candidates: &[(crate::partition::Partitioner, PartitionShape)],
    profile: &CalibProfile,
    knobs: &PredictorKnobs,
) -> Vec<(crate::partition::Partitioner, f64)> {
    let mut out: Vec<(crate::partition::Partitioner, f64)> = candidates
        .iter()
        .map(|(p, shape)| (*p, predict(cfg, data, shape, profile, knobs).total()))
        .collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::Mesh;
    use crate::partition::Partitioner;

    fn prof() -> CalibProfile {
        CalibProfile::perlmutter()
    }

    fn url_shape() -> DataShape {
        DataShape { m: 2_396_130, n: 3_231_961, zbar: 116.0 }
    }

    /// The paper's url measurements at p_c = 64 (§6.5): rows partitioner
    /// κ=33.8 with exact n/p_c slabs; nnz κ=1.3 but a 1.4M-column slab;
    /// cyclic κ=1.9 exact slabs.
    fn url_partitions() -> [(Partitioner, PartitionShape); 3] {
        let n = 3_231_961.0;
        let exact = n / 64.0;
        [
            (
                Partitioner::Rows,
                PartitionShape { kappa: 33.8, n_local_mean: exact, n_local_max: exact },
            ),
            (
                Partitioner::Nnz,
                PartitionShape { kappa: 1.3, n_local_mean: exact, n_local_max: 1_409_992.0 },
            ),
            (
                Partitioner::Cyclic,
                PartitionShape { kappa: 1.9, n_local_mean: exact, n_local_max: exact },
            ),
        ]
    }

    #[test]
    fn url_ranking_is_cyclic_rows_nnz() {
        // §6.5 Validation: "On url and news20 the predicted ranking is
        // cyclic < rows < nnz (cache spill on the latter)".
        let cfg = HybridConfig::new(Mesh::new(4, 64), 4, 32, 10);
        let ranked =
            rank_partitioners(&cfg, &url_shape(), &url_partitions(), &prof(), &{
                PredictorKnobs { syrkd_floor_s_per_col: 2e-10, ..Default::default() }
            });
        let order: Vec<_> = ranked.iter().map(|(p, _)| *p).collect();
        assert_eq!(order, vec![Partitioner::Cyclic, Partitioner::Rows, Partitioner::Nnz]);
    }

    #[test]
    fn balanced_partitions_tie() {
        // rcv1 regime: all partitioners near κ=1 with identical slabs must
        // predict within 5%.
        let data = DataShape { m: 20_242, n: 47_236, zbar: 74.0 };
        let cfg = HybridConfig::new(Mesh::new(1, 16), 4, 32, 10);
        let exact = 47_236.0 / 16.0;
        let mk = |kappa: f64| PartitionShape { kappa, n_local_mean: exact, n_local_max: exact };
        let knobs = PredictorKnobs::default();
        let a = predict(&cfg, &data, &mk(1.01), &prof(), &knobs).total();
        let b = predict(&cfg, &data, &mk(1.62), &prof(), &knobs).total();
        assert!((a - b).abs() / a < 0.35, "a={a} b={b}");
    }

    #[test]
    fn skew_term_zero_at_kappa_one() {
        let data = url_shape();
        let cfg = HybridConfig::new(Mesh::new(4, 64), 4, 32, 10);
        let exact = data.n as f64 / 64.0;
        let shape = PartitionShape { kappa: 1.0, n_local_mean: exact, n_local_max: exact };
        let p = predict(&cfg, &data, &shape, &prof(), &PredictorKnobs::default());
        assert_eq!(p.sstep_skew, 0.0);
    }

    #[test]
    fn skew_grows_with_kappa() {
        let data = url_shape();
        let cfg = HybridConfig::new(Mesh::new(4, 64), 4, 32, 10);
        let exact = data.n as f64 / 64.0;
        let knobs = PredictorKnobs::default();
        let skew = |kappa: f64| {
            let shape = PartitionShape { kappa, n_local_mean: exact, n_local_max: exact };
            predict(&cfg, &data, &shape, &prof(), &knobs).sstep_skew
        };
        assert!(skew(2.0) > 0.0);
        assert!(skew(34.0) > skew(2.0));
        // Approximately linear in (κ − 1), as the paper's T_skew form.
        let ratio = skew(34.0) / skew(2.0);
        assert!((ratio - 33.0).abs() < 8.0, "ratio={ratio}");
    }

    #[test]
    fn cache_spill_penalizes_nnz_even_at_low_kappa() {
        // An 11.2 MB slab prices at L3/DRAM; exact slabs at 400 KB price at
        // L2 — the §6.5 url story.
        let data = url_shape();
        let cfg = HybridConfig::new(Mesh::new(4, 64), 4, 32, 10);
        let exact = data.n as f64 / 64.0;
        let knobs = PredictorKnobs::default();
        let spill = PartitionShape { kappa: 1.3, n_local_mean: exact, n_local_max: 1.4e6 };
        let tight = PartitionShape { kappa: 1.3, n_local_mean: exact, n_local_max: exact };
        let t_spill = predict(&cfg, &data, &spill, &prof(), &knobs).total();
        let t_tight = predict(&cfg, &data, &tight, &prof(), &knobs).total();
        assert!(t_spill > t_tight * 1.1, "spill {t_spill} vs tight {t_tight}");
    }

    #[test]
    fn pinned_linear_reproduces_hockney_comm_terms() {
        use crate::collectives::{AlgoPolicy, Algorithm};
        use crate::costmodel::hockney;
        let data = url_shape();
        let cfg = HybridConfig::new(Mesh::new(4, 64), 4, 32, 10);
        let exact = data.n as f64 / 64.0;
        let shape = PartitionShape { kappa: 1.0, n_local_mean: exact, n_local_max: exact };
        let knobs =
            PredictorKnobs { algo: AlgoPolicy::Fixed(Algorithm::Linear), ..Default::default() };
        let pred = predict(&cfg, &data, &shape, &prof(), &knobs);
        let sb = 128.0;
        let row_words = (sb + sb * (sb + 1.0) / 2.0) as usize;
        let want_row = hockney::allreduce_time(&prof(), 64, row_words) / 4.0;
        assert!((pred.sstep_comm - want_row).abs() < want_row * 1e-12);
        let want_col = hockney::allreduce_time(&prof(), 4, exact as usize) / 10.0;
        assert!((pred.fedavg_comm - want_col).abs() < want_col * 1e-12);
    }

    #[test]
    fn algorithm_policy_moves_predicted_comm() {
        // The full-shard column Allreduce is bandwidth-dominated: pricing
        // it at ring beats recursive doubling, and Auto matches the best.
        use crate::collectives::{AlgoPolicy, Algorithm};
        let data = url_shape();
        let cfg = HybridConfig::new(Mesh::new(64, 4), 2, 32, 10);
        let exact = data.n as f64 / 4.0;
        let shape = PartitionShape { kappa: 1.0, n_local_mean: exact, n_local_max: exact };
        let with = |algo: AlgoPolicy| {
            predict(&cfg, &data, &shape, &prof(), &PredictorKnobs { algo, ..Default::default() })
                .fedavg_comm
        };
        let ring = with(AlgoPolicy::Fixed(Algorithm::RingAllreduce));
        let rd = with(AlgoPolicy::Fixed(Algorithm::RecursiveDoubling));
        let auto = with(AlgoPolicy::Auto);
        assert!(ring < rd, "ring {ring} vs rd {rd}");
        assert!(auto <= ring * (1.0 + 1e-12), "auto {auto} vs ring {ring}");
    }

    #[test]
    fn overlap_knob_moves_comm_into_hidden_without_touching_compute() {
        let data = url_shape();
        let cfg = HybridConfig::new(Mesh::new(4, 64), 4, 32, 10);
        let exact = data.n as f64 / 64.0;
        let shape = PartitionShape { kappa: 1.5, n_local_mean: exact, n_local_max: exact };
        let off = predict(&cfg, &data, &shape, &prof(), &PredictorKnobs::default());
        let bun = predict(
            &cfg,
            &data,
            &shape,
            &prof(),
            &PredictorKnobs { overlap: OverlapPolicy::Bundle, ..Default::default() },
        );
        assert_eq!(off.sstep_hidden, 0.0);
        assert!(bun.sstep_hidden > 0.0);
        assert!(bun.total() <= off.total());
        // Exposed + hidden reconstructs the bulk-synchronous transfer
        // (the skew wait is identical in both).
        let row_off = off.sstep_comm - off.sstep_skew;
        let row_bun = bun.sstep_comm - bun.sstep_skew;
        assert!((row_bun + bun.sstep_hidden - row_off).abs() <= 1e-12 * (1.0 + row_off));
        assert_eq!(off.spgemv, bun.spgemv);
        assert_eq!(off.gram, bun.gram);
        assert_eq!(off.sstep_skew, bun.sstep_skew);
    }

    #[test]
    fn fedavg_comm_amortizes_with_tau() {
        let data = url_shape();
        let exact = data.n as f64 / 64.0;
        let shape = PartitionShape { kappa: 1.0, n_local_mean: exact, n_local_max: exact };
        let knobs = PredictorKnobs::default();
        let t10 = predict(
            &HybridConfig::new(Mesh::new(4, 64), 4, 32, 10),
            &data,
            &shape,
            &prof(),
            &knobs,
        )
        .fedavg_comm;
        let t100 = predict(
            &HybridConfig::new(Mesh::new(4, 64), 4, 32, 100),
            &data,
            &shape,
            &prof(),
            &knobs,
        )
        .fedavg_comm;
        assert!((t10 / t100 - 10.0).abs() < 0.5);
    }
}
