//! The parameter-free topology rule, Eq. (7):
//!
//! `p_c* = max( ⌈n·w / L_cap⌉, min(R, p) )`, `p_r* = p / p_c*`.
//!
//! Holding the row team within one node (`p_c ≤ R`) keeps the frequent row
//! Allreduce on shared-memory transport; sliding `p_c` upward shrinks the
//! `n/p_c` sync payload monotonically inside the intra-node piece, so the
//! kink at `p_c = R` is the optimum. The cache term raises `p_c*` above `R`
//! only when the per-rank weight slab `n·w/p_c` would spill `L_cap` at
//! `p_c = R`. Only two machine constants — `R` and `L_cap` — are needed; no
//! α-β-γ calibration (paper §6.3).

use crate::mesh::Mesh;
use crate::WORD_BYTES;

/// Apply Eq. (7) for a dataset with `n` features on a machine with `R`
/// ranks per node and `L_cap` bytes of per-core cache, at total ranks `p`.
/// The raw rule value is snapped to the nearest *feasible* `p_c` (a divisor
/// of `p`): the smallest divisor ≥ the rule value, or `p` if none.
pub fn mesh_rule(n: usize, p: usize, ranks_per_node: usize, l_cap_bytes: usize) -> Mesh {
    assert!(p >= 1);
    let cache_term = (n * WORD_BYTES).div_ceil(l_cap_bytes);
    let target = cache_term.max(ranks_per_node.min(p)).min(p);
    let p_c = smallest_divisor_at_least(p, target);
    Mesh::new(p / p_c, p_c)
}

/// Is the cache term binding (i.e. does it raise `p_c*` above `min(R, p)`)?
/// On the paper's LIBSVM suite it never binds (`n·w ≤ R·L_cap = 64 MB`).
pub fn cache_term_binding(n: usize, p: usize, ranks_per_node: usize, l_cap_bytes: usize) -> bool {
    (n * WORD_BYTES).div_ceil(l_cap_bytes) > ranks_per_node.min(p)
}

fn smallest_divisor_at_least(p: usize, target: usize) -> usize {
    for d in 1..=p {
        if p % d == 0 && d >= target {
            return d;
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    const R: usize = 64;
    const L_CAP: usize = 1 << 20;

    // The paper's Table 4, verbatim: rule predictions on Perlmutter (R=64,
    // L_cap = 1 MB), cache term non-binding on every LIBSVM entry.
    #[test]
    fn table4_url() {
        let m = mesh_rule(3_231_961, 256, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (4, 64));
    }

    #[test]
    fn table4_synthetic() {
        let m = mesh_rule(3_145_728, 128, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (2, 64));
    }

    #[test]
    fn table4_news20() {
        let m = mesh_rule(1_355_191, 64, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (1, 64));
    }

    #[test]
    fn table4_rcv1() {
        let m = mesh_rule(47_236, 16, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (1, 16));
    }

    #[test]
    fn cache_term_nonbinding_on_libsvm() {
        for &n in &[3_231_961usize, 1_355_191, 47_236, 2_000] {
            assert!(!cache_term_binding(n, 256, R, L_CAP), "n={n}");
        }
    }

    #[test]
    fn cache_term_binds_on_huge_n() {
        // n·w = 800 MB ≫ 64 MB: the rule must push p_c past R.
        let n = 100_000_000;
        assert!(cache_term_binding(n, 2048, R, L_CAP));
        let m = mesh_rule(n, 2048, R, L_CAP);
        assert!(m.p_c > R, "p_c={} should exceed R", m.p_c);
        // And the per-rank slab now fits (or p_c hit its ceiling p).
        assert!(n * WORD_BYTES <= m.p_c * L_CAP || m.p_c == 2048);
    }

    #[test]
    fn rule_saturates_at_small_p() {
        // p < R: the whole machine is one node; rule picks the 1D s-step
        // corner (p_c = p).
        let m = mesh_rule(47_236, 8, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (1, 8));
    }

    #[test]
    fn rule_always_returns_valid_factorization() {
        for p in [1usize, 2, 6, 12, 60, 96, 256, 384] {
            for n in [1usize << 10, 1 << 20, 1 << 27] {
                let m = mesh_rule(n, p, R, L_CAP);
                assert_eq!(m.p(), p, "p={p} n={n} gave {m}");
            }
        }
    }

    #[test]
    fn non_power_of_two_snaps_to_divisor() {
        // p = 96, target 64 → smallest divisor ≥ 64 is 96.
        let m = mesh_rule(1 << 20, 96, R, L_CAP);
        assert_eq!(m.p_c, 96);
        // p = 192, target 64 → divisor 64 exists.
        let m = mesh_rule(1 << 20, 192, R, L_CAP);
        assert_eq!(m.p_c, 64);
    }
}
