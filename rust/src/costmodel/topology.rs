//! The parameter-free topology rule, Eq. (7):
//!
//! `p_c* = max( ⌈n·w / L_cap⌉, min(R, p) )`, `p_r* = p / p_c*`.
//!
//! Holding the row team within one node (`p_c ≤ R`) keeps the frequent row
//! Allreduce on shared-memory transport; sliding `p_c` upward shrinks the
//! `n/p_c` sync payload monotonically inside the intra-node piece, so the
//! kink at `p_c = R` is the optimum. The cache term raises `p_c*` above `R`
//! only when the per-rank weight slab `n·w/p_c` would spill `L_cap` at
//! `p_c = R`. Only two machine constants — `R` and `L_cap` — are needed; no
//! α-β-γ calibration (paper §6.3).

use super::calib::CalibProfile;
use super::model::{eval_algo, DataShape, HybridConfig};
use crate::collectives::AlgoPolicy;
use crate::mesh::Mesh;
use crate::WORD_BYTES;

/// Apply Eq. (7) for a dataset with `n` features on a machine with `R`
/// ranks per node and `L_cap` bytes of per-core cache, at total ranks `p`.
/// The raw rule value is snapped to the nearest *feasible* `p_c` (a divisor
/// of `p`): the smallest divisor ≥ the rule value, or `p` if none.
pub fn mesh_rule(n: usize, p: usize, ranks_per_node: usize, l_cap_bytes: usize) -> Mesh {
    assert!(p >= 1);
    let cache_term = (n * WORD_BYTES).div_ceil(l_cap_bytes);
    let target = cache_term.max(ranks_per_node.min(p)).min(p);
    let p_c = smallest_divisor_at_least(p, target);
    Mesh::new(p / p_c, p_c)
}

/// Is the cache term binding (i.e. does it raise `p_c*` above `min(R, p)`)?
/// On the paper's LIBSVM suite it never binds (`n·w ≤ R·L_cap = 64 MB`).
pub fn cache_term_binding(n: usize, p: usize, ranks_per_node: usize, l_cap_bytes: usize) -> bool {
    (n * WORD_BYTES).div_ceil(l_cap_bytes) > ranks_per_node.min(p)
}

/// Collective-algorithm-aware mesh selection: the Eq. (4) argmin over all
/// factorizations `p_r · p_c = p`, priced under `policy`.
///
/// Eq. (7) is parameter-free because under the *fixed* Hockney bound the
/// `n/p_c` sync payload shrinks monotonically in `p_c` up to the node
/// boundary kink. Once the collective algorithm switches with payload
/// (ring for the huge FedAvg shard, recursive doubling for the small Gram
/// message), the crossover moves with it — this rule re-derives the best
/// mesh from the algorithm-aware model instead of the two machine
/// constants. `s` is clamped to 1 at the FedAvg corner (`p_c = 1`), `τ`
/// raised to `s` where needed, matching the experiment drivers.
pub fn mesh_rule_costed(
    data: &DataShape,
    p: usize,
    s: usize,
    b: usize,
    tau: usize,
    profile: &CalibProfile,
    policy: AlgoPolicy,
) -> Mesh {
    assert!(p >= 1);
    Mesh::factorizations(p)
        .into_iter()
        .min_by(|a, b_mesh| {
            let ta = eval_algo(&costed_cfg(*a, s, b, tau), data, profile, policy).total();
            let tb = eval_algo(&costed_cfg(*b_mesh, s, b, tau), data, profile, policy).total();
            ta.partial_cmp(&tb).unwrap()
        })
        .expect("factorizations are nonempty")
}

/// The sweep configuration at one mesh (s clamped at the FedAvg corner).
fn costed_cfg(mesh: Mesh, s: usize, b: usize, tau: usize) -> HybridConfig {
    let s = if mesh.p_c == 1 { 1 } else { s };
    HybridConfig::new(mesh, s, b, tau.max(s))
}

fn smallest_divisor_at_least(p: usize, target: usize) -> usize {
    for d in 1..=p {
        if p % d == 0 && d >= target {
            return d;
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    const R: usize = 64;
    const L_CAP: usize = 1 << 20;

    // The paper's Table 4, verbatim: rule predictions on Perlmutter (R=64,
    // L_cap = 1 MB), cache term non-binding on every LIBSVM entry.
    #[test]
    fn table4_url() {
        let m = mesh_rule(3_231_961, 256, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (4, 64));
    }

    #[test]
    fn table4_synthetic() {
        let m = mesh_rule(3_145_728, 128, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (2, 64));
    }

    #[test]
    fn table4_news20() {
        let m = mesh_rule(1_355_191, 64, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (1, 64));
    }

    #[test]
    fn table4_rcv1() {
        let m = mesh_rule(47_236, 16, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (1, 16));
    }

    #[test]
    fn cache_term_nonbinding_on_libsvm() {
        for &n in &[3_231_961usize, 1_355_191, 47_236, 2_000] {
            assert!(!cache_term_binding(n, 256, R, L_CAP), "n={n}");
        }
    }

    #[test]
    fn cache_term_binds_on_huge_n() {
        // n·w = 800 MB ≫ 64 MB: the rule must push p_c past R.
        let n = 100_000_000;
        assert!(cache_term_binding(n, 2048, R, L_CAP));
        let m = mesh_rule(n, 2048, R, L_CAP);
        assert!(m.p_c > R, "p_c={} should exceed R", m.p_c);
        // And the per-rank slab now fits (or p_c hit its ceiling p).
        assert!(n * WORD_BYTES <= m.p_c * L_CAP || m.p_c == 2048);
    }

    #[test]
    fn rule_saturates_at_small_p() {
        // p < R: the whole machine is one node; rule picks the 1D s-step
        // corner (p_c = p).
        let m = mesh_rule(47_236, 8, R, L_CAP);
        assert_eq!((m.p_r, m.p_c), (1, 8));
    }

    #[test]
    fn rule_always_returns_valid_factorization() {
        for p in [1usize, 2, 6, 12, 60, 96, 256, 384] {
            for n in [1usize << 10, 1 << 20, 1 << 27] {
                let m = mesh_rule(n, p, R, L_CAP);
                assert_eq!(m.p(), p, "p={p} n={n} gave {m}");
            }
        }
    }

    #[test]
    fn costed_rule_returns_valid_factorizations() {
        use crate::collectives::AlgoPolicy;
        let prof = CalibProfile::perlmutter();
        let data = DataShape { m: 100_000, n: 3_000_000, zbar: 100.0 };
        for p in [1usize, 2, 6, 16, 96, 256] {
            let m = mesh_rule_costed(&data, p, 4, 32, 10, &prof, AlgoPolicy::Auto);
            assert_eq!(m.p(), p, "p={p} gave {m}");
        }
    }

    #[test]
    fn costed_rule_is_no_worse_than_eq7_under_same_pricing() {
        use crate::collectives::AlgoPolicy;
        let prof = CalibProfile::perlmutter();
        // url-shaped: huge n, sparse.
        let data = DataShape { m: 2_396_130, n: 3_231_961, zbar: 116.0 };
        let p = 256;
        for policy in [AlgoPolicy::Auto] {
            let costed = mesh_rule_costed(&data, p, 4, 32, 10, &prof, policy);
            let eq7 = mesh_rule(data.n, p, R, L_CAP);
            let t = |mesh: Mesh| {
                eval_algo(&costed_cfg(mesh, 4, 32, 10), &data, &prof, policy).total()
            };
            assert!(t(costed) <= t(eq7) * (1.0 + 1e-12), "{costed} vs {eq7}");
            // And on the url shape the costed rule still wants a wide row
            // team (the sync shard must shrink): p_c well above 1.
            assert!(costed.p_c >= 16, "costed rule picked {costed}");
        }
    }

    #[test]
    fn non_power_of_two_snaps_to_divisor() {
        // p = 96, target 64 → smallest divisor ≥ 64 is 96.
        let m = mesh_rule(1 << 20, 96, R, L_CAP);
        assert_eq!(m.p_c, 96);
        // p = 192, target 64 → divisor 64 exists.
        let m = mesh_rule(1 << 20, 192, R, L_CAP);
        assert_eq!(m.p_c, 64);
    }
}
