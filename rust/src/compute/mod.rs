//! Pluggable dense-compute backends.
//!
//! The solvers' sparse work (SpMV, Gram, scatter updates) runs on the CSR
//! substrate in [`crate::sparse`]; the *dense, shape-static* hot spots — the
//! s-step correction recurrence, the dense mini-batch gradient, the
//! numerically-stable loss reduction — go through this trait so they can be
//! served either by
//!
//! * [`native::NativeBackend`] — pure Rust `f64`, always available, the
//!   correctness reference on the Rust side; or
//! * [`crate::runtime::XlaBackend`] — the AOT-compiled JAX + Pallas
//!   artifacts executed via PJRT (the three-layer architecture's L1/L2),
//!   loaded from `artifacts/*.hlo.txt` at startup. Python never runs at
//!   request time.
//!
//! The two backends are parity-tested against each other and against the
//! Python `ref.py` oracle (see `rust/tests/` and `python/tests/`).

pub mod native;

pub use native::NativeBackend;

/// Dense compute operations used on the solver hot path.
pub trait ComputeBackend: Sync {
    /// Backend name for logs/benches.
    fn name(&self) -> &'static str;

    /// Elementwise logistic residual `out[i] = 1 / (1 + exp(v[i]))`
    /// (Algorithm 1 line 4 with labels folded into the matrix).
    fn sigmoid_residual(&self, v: &[f64], out: &mut [f64]);

    /// The s-step correction recurrence (Algorithm 3 lines 9–14).
    ///
    /// Inputs: `g` — the `sb × sb` lower-triangular Gram `tril(YYᵀ)`
    /// (row-major, upper triangle ignored); `v = Y·x_sk` (`sb`);
    /// `eta_over_b = η/b`. Output `z` (`sb`): for each step `j`,
    /// `t_j = v_j + (η/b)·Σ_{l<j} G[j,l]·z_l`, then
    /// `z_j = 1/(1 + exp(t_j))` — the corrected residuals whose scatter
    /// `x += (η/b)·Yᵀz` advances the weights by `s` SGD steps at once.
    fn sstep_correct(
        &self,
        s: usize,
        b: usize,
        g: &[f64],
        v: &[f64],
        eta_over_b: f64,
        z: &mut [f64],
    );

    /// Dense mini-batch logistic gradient step:
    /// `margins = A_blk·x` (`A_blk` row-major `b × n`, labels folded),
    /// `u = 1/(1+exp(margins))`, `x ← x + (η/b)·A_blkᵀ·u`, in place.
    /// (The dense/epsilon path.)
    fn dense_grad_step(&self, b: usize, n: usize, a_blk: &[f64], x: &mut [f64], eta: f64);

    /// Numerically-stable logistic loss reduction:
    /// `Σ_i log(1 + exp(−margins[i]))` (caller divides by m).
    fn loss_sum(&self, margins: &[f64]) -> f64;
}

/// Backend conformance suite: any `ComputeBackend` must pass these.
/// Public so the runtime crate tests can run it against the XLA backend.
pub fn conformance_suite(be: &dyn ComputeBackend) {
    conformance::sigmoid_matches_scalar(be);
    conformance::sstep_with_zero_gram_is_plain_sigmoid(be);
    conformance::sstep_matches_sequential_sgd_reference(be);
    conformance::dense_grad_matches_hand_rolled(be);
    conformance::loss_sum_is_stable(be);
}

mod conformance {
    use super::*;
    use crate::util::Prng;

    pub fn sigmoid_matches_scalar(be: &dyn ComputeBackend) {
        let v = [-30.0, -1.0, 0.0, 1.0, 30.0, 700.0, -700.0];
        let mut out = [0.0; 7];
        be.sigmoid_residual(&v, &mut out);
        for (i, &t) in v.iter().enumerate() {
            let want = if t > 500.0 { 0.0 } else { 1.0 / (1.0 + t.exp()) };
            assert!((out[i] - want).abs() < 1e-12, "t={t}: {} vs {want}", out[i]);
        }
    }

    pub fn sstep_with_zero_gram_is_plain_sigmoid(be: &dyn ComputeBackend) {
        let (s, b) = (3, 4);
        let g = vec![0.0; (s * b) * (s * b)];
        let v: Vec<f64> = (0..s * b).map(|i| (i as f64 - 6.0) / 3.0).collect();
        let mut z = vec![0.0; s * b];
        be.sstep_correct(s, b, &g, &v, 0.1, &mut z);
        let mut want = vec![0.0; s * b];
        be.sigmoid_residual(&v, &mut want);
        for i in 0..s * b {
            assert!((z[i] - want[i]).abs() < 1e-12);
        }
    }

    /// The defining property (paper §5.1): s-step SGD is an algebraic
    /// reformulation of SGD and converges identically up to fp error. Run
    /// s sequential SGD steps directly on a small dense problem and check
    /// the bundle produces the same final weights.
    pub fn sstep_matches_sequential_sgd_reference(be: &dyn ComputeBackend) {
        let mut rng = Prng::new(42);
        let (s, b, n) = (4usize, 3usize, 8usize);
        let eta = 0.5;
        // Dense rows of Y (labels already folded).
        let y: Vec<f64> = (0..s * b * n).map(|_| rng.next_gaussian()).collect();
        let x0: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();

        // Reference: s plain SGD steps.
        let mut x_ref = x0.clone();
        for j in 0..s {
            let mut t = vec![0.0; b];
            for i in 0..b {
                let row = &y[(j * b + i) * n..(j * b + i + 1) * n];
                t[i] = row.iter().zip(&x_ref).map(|(a, b)| a * b).sum();
            }
            let mut u = vec![0.0; b];
            be.sigmoid_residual(&t, &mut u);
            for i in 0..b {
                let row = &y[(j * b + i) * n..(j * b + i + 1) * n];
                for c in 0..n {
                    x_ref[c] += eta / b as f64 * u[i] * row[c];
                }
            }
        }

        // Bundle: G = tril(YYᵀ), v = Y·x0, correct, then x = x0 + η/b·Yᵀz.
        let q = s * b;
        let mut g = vec![0.0; q * q];
        for i in 0..q {
            for l in 0..=i {
                g[i * q + l] = (0..n).map(|c| y[i * n + c] * y[l * n + c]).sum();
            }
        }
        let v: Vec<f64> =
            (0..q).map(|i| (0..n).map(|c| y[i * n + c] * x0[c]).sum()).collect();
        let mut z = vec![0.0; q];
        be.sstep_correct(s, b, &g, &v, eta / b as f64, &mut z);
        let mut x_bundle = x0;
        for i in 0..q {
            for c in 0..n {
                x_bundle[c] += eta / b as f64 * z[i] * y[i * n + c];
            }
        }
        for c in 0..n {
            assert!(
                (x_bundle[c] - x_ref[c]).abs() < 1e-10,
                "weight {c}: bundle {} vs sequential {}",
                x_bundle[c],
                x_ref[c]
            );
        }
    }

    pub fn dense_grad_matches_hand_rolled(be: &dyn ComputeBackend) {
        let mut rng = Prng::new(7);
        let (b, n) = (5usize, 6usize);
        let a: Vec<f64> = (0..b * n).map(|_| rng.next_gaussian()).collect();
        let x0: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let eta = 0.3;

        let mut x_got = x0.clone();
        be.dense_grad_step(b, n, &a, &mut x_got, eta);

        let mut x_want = x0;
        let mut t = vec![0.0; b];
        for i in 0..b {
            t[i] = (0..n).map(|c| a[i * n + c] * x_want[c]).sum();
        }
        let mut u = vec![0.0; b];
        be.sigmoid_residual(&t, &mut u);
        for i in 0..b {
            for c in 0..n {
                x_want[c] += eta / b as f64 * u[i] * a[i * n + c];
            }
        }
        for c in 0..n {
            assert!((x_got[c] - x_want[c]).abs() < 1e-12);
        }
    }

    pub fn loss_sum_is_stable(be: &dyn ComputeBackend) {
        let margins = [0.0, 1.0, -1.0, 100.0, -100.0, 800.0, -800.0];
        let got = be.loss_sum(&margins);
        let want: f64 = margins.iter().map(|&m| crate::data::stable_log1p_exp(-m)).sum();
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        assert!(got.is_finite());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_conformance() {
        conformance_suite(&NativeBackend);
    }
}
