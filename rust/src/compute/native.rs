//! Pure-Rust `f64` compute backend — the Rust-side correctness reference
//! and the default hot path when the XLA artifacts are not built.
//!
//! The s-step correction here is the **register-tiled** kernel of the
//! bundle working-set layer: the recurrence's dense `(b × j·b)·(j·b)`
//! products are computed four output rows at a time (one pass over the
//! already-corrected prefix `z[..j·b]` feeds four accumulators, so the
//! prefix is loaded once per tile instead of once per row), and the
//! logistic residual is fused into the row epilogue (no `t` staging
//! buffer — the kernel allocates nothing). Each accumulator still sums in
//! exactly the seed's `l` order, so results are **bit-identical** to the
//! scalar kernel — the repo's standing invariant, pinned by the
//! conformance suite, `tests/xla_parity.rs`, and the old-vs-new rows in
//! `benches/ablation_hotpath.rs`.
//!
//! The numerically-guarded logistic residual lives in one shared
//! [`sigmoid_residual_scalar`] helper (the seed duplicated it across
//! three kernels).

use super::ComputeBackend;

/// Numerically-stable logistic residual `σ(−t) = 1/(1 + eᵗ)`.
///
/// Stable for `t ≥ 0` directly; for very negative `t` the `exp`
/// underflows to 0 giving exactly 1.0 — also fine. Only `t → +inf` needs
/// the early exit to avoid `exp` overflow → `inf`, which still divides to
/// 0.0 correctly, so no branch is needed beyond NaN protection.
#[inline]
pub(crate) fn sigmoid_residual_scalar(t: f64) -> f64 {
    if t > 700.0 {
        0.0
    } else {
        1.0 / (1.0 + t.exp())
    }
}

/// Zero-sized native backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn sigmoid_residual(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), out.len());
        for (o, &t) in out.iter_mut().zip(v) {
            *o = sigmoid_residual_scalar(t);
        }
    }

    fn sstep_correct(
        &self,
        s: usize,
        b: usize,
        g: &[f64],
        v: &[f64],
        eta_over_b: f64,
        z: &mut [f64],
    ) {
        let q = s * b;
        assert_eq!(g.len(), q * q, "gram size");
        assert_eq!(v.len(), q, "v size");
        assert_eq!(z.len(), q, "z size");
        for j in 0..s {
            let row0 = j * b;
            // z[..row0] is the corrected prefix this block's products
            // read; z[row0..] is where the block's residuals land. The
            // split lets the fused epilogue write while the prefix stays
            // borrowed.
            let (done, todo) = z.split_at_mut(row0);
            // t_i = v_i + η/b · Σ_{l<j·b} G[row_i, l] · z_l, then
            // z_i = σ(−t_i), four rows per tile. Each accumulator sums in
            // the same `l` order as the scalar loop: bit-identical.
            let mut i = 0;
            while i + 4 <= b {
                let g0 = &g[(row0 + i) * q..(row0 + i) * q + row0];
                let g1 = &g[(row0 + i + 1) * q..(row0 + i + 1) * q + row0];
                let g2 = &g[(row0 + i + 2) * q..(row0 + i + 2) * q + row0];
                let g3 = &g[(row0 + i + 3) * q..(row0 + i + 3) * q + row0];
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                for (l, &zl) in done.iter().enumerate() {
                    a0 += g0[l] * zl;
                    a1 += g1[l] * zl;
                    a2 += g2[l] * zl;
                    a3 += g3[l] * zl;
                }
                todo[i] = sigmoid_residual_scalar(v[row0 + i] + eta_over_b * a0);
                todo[i + 1] = sigmoid_residual_scalar(v[row0 + i + 1] + eta_over_b * a1);
                todo[i + 2] = sigmoid_residual_scalar(v[row0 + i + 2] + eta_over_b * a2);
                todo[i + 3] = sigmoid_residual_scalar(v[row0 + i + 3] + eta_over_b * a3);
                i += 4;
            }
            // Remainder rows (b mod 4), scalar.
            while i < b {
                let gi = &g[(row0 + i) * q..(row0 + i) * q + row0];
                let mut acc = 0.0;
                for (gv, zl) in gi.iter().zip(done.iter()) {
                    acc += gv * zl;
                }
                todo[i] = sigmoid_residual_scalar(v[row0 + i] + eta_over_b * acc);
                i += 1;
            }
        }
    }

    fn dense_grad_step(&self, b: usize, n: usize, a_blk: &[f64], x: &mut [f64], eta: f64) {
        assert_eq!(a_blk.len(), b * n, "a_blk size");
        assert_eq!(x.len(), n, "x size");
        let mut u = vec![0.0f64; b];
        for i in 0..b {
            let row = &a_blk[i * n..(i + 1) * n];
            let mut acc = 0.0;
            for (a, xv) in row.iter().zip(x.iter()) {
                acc += a * xv;
            }
            u[i] = sigmoid_residual_scalar(acc);
        }
        let scale = eta / b as f64;
        for i in 0..b {
            let c = scale * u[i];
            if c == 0.0 {
                continue;
            }
            let row = &a_blk[i * n..(i + 1) * n];
            for (xv, a) in x.iter_mut().zip(row) {
                *xv += c * a;
            }
        }
    }

    fn loss_sum(&self, margins: &[f64]) -> f64 {
        margins.iter().map(|&m| crate::data::stable_log1p_exp(-m)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::ComputeBackend;

    #[test]
    fn sigmoid_extremes() {
        let be = NativeBackend;
        let mut out = [0.0; 3];
        be.sigmoid_residual(&[1e308, -1e308, 0.0], &mut out);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 1.0);
        assert_eq!(out[2], 0.5);
    }

    #[test]
    fn correction_uses_only_lower_triangle() {
        let be = NativeBackend;
        let (s, b) = (2, 2);
        let q = s * b;
        let mut g = vec![0.0; q * q];
        // Fill upper triangle with garbage; must not affect the result.
        for i in 0..q {
            for j in (i + 1)..q {
                g[i * q + j] = f64::NAN;
            }
        }
        g[2 * q] = 1.0; // G[2,0]
        let v = vec![0.1, 0.2, 0.3, 0.4];
        let mut z = vec![0.0; q];
        be.sstep_correct(s, b, &g, &v, 0.5, &mut z);
        assert!(z.iter().all(|x| x.is_finite()), "z={z:?}");
    }

    /// The register tile is a pure access-pattern change: the tiled
    /// kernel must match the seed scalar recurrence bit for bit across
    /// block sizes on both sides of the 4-wide tile (including the
    /// remainder rows of b mod 4 ≠ 0).
    #[test]
    fn tiled_correction_bit_identical_to_scalar_reference() {
        // The seed scalar kernel, kept verbatim as the oracle.
        fn scalar_ref(s: usize, b: usize, g: &[f64], v: &[f64], eta_over_b: f64, z: &mut [f64]) {
            let q = s * b;
            let mut t = vec![0.0f64; b];
            for j in 0..s {
                let row0 = j * b;
                for i in 0..b {
                    let gi = &g[(row0 + i) * q..(row0 + i) * q + row0];
                    let mut acc = 0.0;
                    for (gv, zv) in gi.iter().zip(&z[..row0]) {
                        acc += gv * zv;
                    }
                    t[i] = v[row0 + i] + eta_over_b * acc;
                }
                for i in 0..b {
                    z[row0 + i] = sigmoid_residual_scalar(t[i]);
                }
            }
        }
        let be = NativeBackend;
        let mut rng = crate::util::Prng::new(0x71E5);
        for &(s, b) in &[(1usize, 1usize), (2, 3), (3, 4), (2, 5), (4, 8), (3, 7), (2, 13)] {
            let q = s * b;
            let g: Vec<f64> = (0..q * q).map(|_| rng.next_gaussian()).collect();
            let v: Vec<f64> = (0..q).map(|_| rng.next_gaussian()).collect();
            let mut z_tiled = vec![0.0; q];
            be.sstep_correct(s, b, &g, &v, 0.125, &mut z_tiled);
            let mut z_ref = vec![0.0; q];
            scalar_ref(s, b, &g, &v, 0.125, &mut z_ref);
            for (a, r) in z_tiled.iter().zip(&z_ref) {
                assert_eq!(a.to_bits(), r.to_bits(), "s={s} b={b}: {a} vs {r}");
            }
        }
    }

    #[test]
    fn dense_grad_reduces_loss() {
        let be = NativeBackend;
        // Separable toy data: labels folded so all margins should grow.
        let a = vec![1.0, 0.0, 0.0, 1.0, 0.5, 0.5];
        let mut x = vec![0.0, 0.0];
        for _ in 0..200 {
            be.dense_grad_step(3, 2, &a, &mut x, 0.5);
        }
        // All folded margins positive → loss well below log 2.
        let margins: Vec<f64> =
            (0..3).map(|i| a[i * 2] * x[0] + a[i * 2 + 1] * x[1]).collect();
        let loss = be.loss_sum(&margins) / 3.0;
        assert!(loss < 0.3, "loss={loss}");
    }
}
