//! Pure-Rust `f64` compute backend — the Rust-side correctness reference
//! and the default hot path when the XLA artifacts are not built.

use super::ComputeBackend;

/// Zero-sized native backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn sigmoid_residual(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), out.len());
        for (o, &t) in out.iter_mut().zip(v) {
            // 1/(1+exp(t)) is stable for t ≥ 0; for very negative t the
            // exp underflows to 0 giving exactly 1.0 — also fine. Only
            // t → +inf needs the early exit to avoid exp overflow → inf,
            // which still divides to 0.0 correctly, so no branch needed
            // beyond NaN protection.
            *o = if t > 700.0 { 0.0 } else { 1.0 / (1.0 + t.exp()) };
        }
    }

    fn sstep_correct(
        &self,
        s: usize,
        b: usize,
        g: &[f64],
        v: &[f64],
        eta_over_b: f64,
        z: &mut [f64],
    ) {
        let q = s * b;
        assert_eq!(g.len(), q * q, "gram size");
        assert_eq!(v.len(), q, "v size");
        assert_eq!(z.len(), q, "z size");
        let mut t = vec![0.0f64; b];
        for j in 0..s {
            let row0 = j * b;
            // t = v_j + η/b · Σ_{l<j} G[j-block, l-block] · z_l
            // (one dense (b × j·b)·(j·b) product against already-computed z).
            for i in 0..b {
                let gi = &g[(row0 + i) * q..(row0 + i) * q + row0];
                let mut acc = 0.0;
                for (gv, zv) in gi.iter().zip(&z[..row0]) {
                    acc += gv * zv;
                }
                t[i] = v[row0 + i] + eta_over_b * acc;
            }
            // z_j = sigmoid residual of t.
            for i in 0..b {
                z[row0 + i] = if t[i] > 700.0 { 0.0 } else { 1.0 / (1.0 + t[i].exp()) };
            }
        }
    }

    fn dense_grad_step(&self, b: usize, n: usize, a_blk: &[f64], x: &mut [f64], eta: f64) {
        assert_eq!(a_blk.len(), b * n, "a_blk size");
        assert_eq!(x.len(), n, "x size");
        let mut u = vec![0.0f64; b];
        for i in 0..b {
            let row = &a_blk[i * n..(i + 1) * n];
            let mut acc = 0.0;
            for (a, xv) in row.iter().zip(x.iter()) {
                acc += a * xv;
            }
            u[i] = if acc > 700.0 { 0.0 } else { 1.0 / (1.0 + acc.exp()) };
        }
        let scale = eta / b as f64;
        for i in 0..b {
            let c = scale * u[i];
            if c == 0.0 {
                continue;
            }
            let row = &a_blk[i * n..(i + 1) * n];
            for (xv, a) in x.iter_mut().zip(row) {
                *xv += c * a;
            }
        }
    }

    fn loss_sum(&self, margins: &[f64]) -> f64 {
        margins.iter().map(|&m| crate::data::stable_log1p_exp(-m)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::ComputeBackend;

    #[test]
    fn sigmoid_extremes() {
        let be = NativeBackend;
        let mut out = [0.0; 3];
        be.sigmoid_residual(&[1e308, -1e308, 0.0], &mut out);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 1.0);
        assert_eq!(out[2], 0.5);
    }

    #[test]
    fn correction_uses_only_lower_triangle() {
        let be = NativeBackend;
        let (s, b) = (2, 2);
        let q = s * b;
        let mut g = vec![0.0; q * q];
        // Fill upper triangle with garbage; must not affect the result.
        for i in 0..q {
            for j in (i + 1)..q {
                g[i * q + j] = f64::NAN;
            }
        }
        g[2 * q] = 1.0; // G[2,0]
        let v = vec![0.1, 0.2, 0.3, 0.4];
        let mut z = vec![0.0; q];
        be.sstep_correct(s, b, &g, &v, 0.5, &mut z);
        assert!(z.iter().all(|x| x.is_finite()), "z={z:?}");
    }

    #[test]
    fn dense_grad_reduces_loss() {
        let be = NativeBackend;
        // Separable toy data: labels folded so all margins should grow.
        let a = vec![1.0, 0.0, 0.0, 1.0, 0.5, 0.5];
        let mut x = vec![0.0, 0.0];
        for _ in 0..200 {
            be.dense_grad_step(3, 2, &a, &mut x, 0.5);
        }
        // All folded margins positive → loss well below log 2.
        let margins: Vec<f64> =
            (0..3).map(|i| a[i * 2] * x[0] + a[i * 2 + 1] * x[1]).collect();
        let loss = be.loss_sum(&margins) / 3.0;
        assert!(loss < 0.3, "loss={loss}");
    }
}
