//! Nonzero-distribution statistics.
//!
//! Real sparse data (rcv1, news20, url) has heavy-tailed nonzero-per-row and
//! nonzero-per-column distributions (paper §1); these statistics quantify
//! the skew and feed both the partitioning study (§7.3) and the
//! load-imbalance refinement `κ` (§6.5).

use super::csr::Csr;
use crate::util::Summary;

/// Per-column nonzero counts ("column degrees").
pub fn col_degrees(a: &Csr) -> Vec<usize> {
    let mut deg = vec![0usize; a.cols()];
    for &c in a.indices() {
        deg[c as usize] += 1;
    }
    deg
}

/// Per-row nonzero counts.
pub fn row_degrees(a: &Csr) -> Vec<usize> {
    (0..a.rows()).map(|r| a.row_nnz(r)).collect()
}

/// Aggregate skew diagnostics for a matrix.
#[derive(Clone, Debug)]
pub struct NnzStats {
    /// Summary over per-row nnz.
    pub rows: Summary,
    /// Summary over per-column nnz.
    pub cols: Summary,
    /// Fraction of total nnz held by the heaviest 1% of columns — the
    /// "heavy-tail share" that separates url-like from uniform data.
    pub top1pct_col_share: f64,
    /// Gini coefficient of the column-degree distribution (0 = uniform).
    pub col_gini: f64,
}

impl NnzStats {
    /// Compute all diagnostics for `a`.
    pub fn of(a: &Csr) -> NnzStats {
        let rdeg = row_degrees(a);
        let cdeg = col_degrees(a);
        let rows = Summary::of_counts(&rdeg);
        let cols = Summary::of_counts(&cdeg);

        let mut sorted = cdeg.clone();
        sorted.sort_unstable_by(|x, y| y.cmp(x)); // descending
        let total: usize = sorted.iter().sum();
        let k = (sorted.len().max(100) / 100).max(1);
        let top: usize = sorted.iter().take(k).sum();
        let top1pct_col_share = if total == 0 { 0.0 } else { top as f64 / total as f64 };

        NnzStats { rows, cols, top1pct_col_share, col_gini: gini(&cdeg) }
    }
}

/// Gini coefficient of a count distribution (0 uniform, →1 concentrated).
pub fn gini(counts: &[usize]) -> f64 {
    if counts.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    let sum: f64 = v.iter().sum();
    if sum == 0.0 {
        return 0.0;
    }
    let weighted: f64 = v.iter().enumerate().map(|(i, &x)| (i as f64 + 1.0) * x).sum();
    (2.0 * weighted) / (n * sum) - (n + 1.0) / n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn degrees_count_correctly() {
        let a = Csr::from_triplets(3, 3, &[(0, 0, 1.0), (1, 0, 1.0), (2, 0, 1.0), (2, 2, 1.0)]);
        assert_eq!(col_degrees(&a), vec![3, 0, 1]);
        assert_eq!(row_degrees(&a), vec![1, 1, 2]);
    }

    #[test]
    fn gini_uniform_is_zero() {
        assert!(gini(&[5, 5, 5, 5]).abs() < 1e-12);
    }

    #[test]
    fn gini_concentrated_is_high() {
        let g = gini(&[0, 0, 0, 100]);
        assert!(g > 0.7, "g={g}");
    }

    #[test]
    fn gini_monotone_in_skew() {
        let lo = gini(&[4, 5, 6, 5]);
        let hi = gini(&[1, 1, 1, 17]);
        assert!(hi > lo);
    }

    #[test]
    fn skewed_matrix_detected() {
        // Column 0 holds half of all nonzeros.
        let mut t = Vec::new();
        for r in 0..100 {
            t.push((r, 0usize, 1.0));
            t.push((r, 1 + (r % 99), 1.0));
        }
        let a = Csr::from_triplets(100, 100, &t);
        let s = NnzStats::of(&a);
        assert!(s.cols.imbalance() > 10.0, "imbalance={}", s.cols.imbalance());
        assert!(s.top1pct_col_share >= 0.5);
        // Rows are perfectly balanced.
        assert!((s.rows.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn uniform_random_matrix_is_balanced() {
        let mut rng = Prng::new(31);
        let a = Csr::random(500, 200, 10, &mut rng);
        let s = NnzStats::of(&a);
        assert!(s.cols.imbalance() < 2.5, "imbalance={}", s.cols.imbalance());
        assert!(s.col_gini < 0.3, "gini={}", s.col_gini);
    }
}
