//! Bundle working-set layer — the materialized `Y` stack of Algorithm 3.
//!
//! The per-bundle hot path (sample → SpMV → `G = tril(Y·Yᵀ)` → s-step
//! correction → transpose-SpMV) operates on the `q = s·b` sampled,
//! label-scaled rows. The seed kernels re-read those rows through
//! `row_ids` indirection into the full `m_local × n_local` CSR block on
//! *every* pass — and the Gram alone makes `q` passes. [`BundleCsr`]
//! gathers the sampled rows **once** per bundle into a compact,
//! cache-contiguous CSR stack (own indptr/indices/values, rebuilt in
//! place into reusable per-rank scratch — zero steady-state allocation),
//! which is exactly the `sb × n_local` matrix the paper's
//! `mkl_sparse_syrkd` inspector-executor analysis (§6.5) operates on:
//! the inspector's gather is paid once, every executor pass streams a
//! packed working set that fits a faster cache tier than the scattered
//! parent rows.
//!
//! Kernel equivalence contract: every kernel here performs **exactly the
//! seed kernel's floating-point operations in exactly the seed order**
//! ([`BundleCsr::spmv`] ↔ [`Csr::spmv_rows`], [`BundleCsr::t_spmv_acc`] ↔
//! [`Csr::t_spmv_rows_acc`], and the gathered Gram kernels in
//! [`super::gram`]), so solver trajectories are bit-identical to the
//! seed — the repo's standing invariant, pinned by the property tests
//! below and by `tests/session_equivalence.rs`.
//!
//! [`GramStrategy`] is the merge-vs-scatter knob for the Gram kernel;
//! its `Auto` mode resolves per rank block from the block's measured
//! mean row density (see [`GramStrategy::resolve`]). Merge and scatter
//! are themselves bit-identical (a tested property), so the knob — like
//! every collective/overlap knob in this repo — can move wall time,
//! never values.

use super::csr::Csr;

/// Strategy knob for the bundle Gram kernel `G = tril(Y·Yᵀ)` (threaded
/// through `RunOpts::gram` / `SessionBuilder::gram` / CLI `--gram`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GramStrategy {
    /// Row-pair merge joins ([`super::gram::gram_lower_gathered`]):
    /// branchy two-pointer walks, no dense scratch traffic. Wins when
    /// rows are short (sparse intersections exit early).
    Merge,
    /// Dense-accumulator scatter/gather
    /// ([`super::gram::gram_lower_scatter_gathered`]): one branch-free
    /// multiply-add per stored entry against an `n_local` scratch — the
    /// `mkl_sparse_syrkd` executor structure. Wins when rows are denser.
    Scatter,
    /// Resolve per rank block from its measured mean row density
    /// (`z̄ < `[`GRAM_MERGE_MAX_ZBAR`]` → Merge, else Scatter`). The
    /// default.
    Auto,
}

/// `Auto` crossover: blocks whose mean row density is below this pick
/// the merge Gram, denser blocks the scatter Gram.
///
/// Rationale (and the measuring instrument): per row pair, merge walks
/// `~z_i + z_j` branchy comparisons with early exit, scatter does `~z_j`
/// branch-free multiply-adds plus an `O(z_i)` scatter/clean amortized
/// over the pair row — so scatter's per-entry work is cheaper once rows
/// carry enough entries to amortize its scratch traffic, and merge wins
/// in the short-row regime. `benches/ablation_hotpath.rs` sweeps z̄
/// across the crossover on the 4096×8192 synthetic config and prints
/// the measured merge/scatter ratio per density (folded into
/// `BENCH_ci.json` by `tools/collect_bench.py`), so the shipped
/// constant is checked against the current hardware on every CI run.
pub const GRAM_MERGE_MAX_ZBAR: f64 = 12.0;

impl GramStrategy {
    /// CLI/table label.
    pub fn name(&self) -> &'static str {
        match self {
            GramStrategy::Merge => "merge",
            GramStrategy::Scatter => "scatter",
            GramStrategy::Auto => "auto",
        }
    }

    /// Resolve `Auto` against a block's measured mean row density
    /// (`zbar = `[`Csr::mean_row_nnz`]). Fixed strategies return
    /// themselves; the result is never `Auto`.
    pub fn resolve(self, zbar: f64) -> GramStrategy {
        match self {
            GramStrategy::Auto => {
                if zbar < GRAM_MERGE_MAX_ZBAR {
                    GramStrategy::Merge
                } else {
                    GramStrategy::Scatter
                }
            }
            fixed => fixed,
        }
    }
}

crate::impl_enum_from_str!(GramStrategy, "gram strategy",
    ("merge" => GramStrategy::Merge),
    ("scatter" => GramStrategy::Scatter),
    ("auto" => GramStrategy::Auto),
);

/// The gathered bundle stack `Y`: a compact CSR holding the sampled rows
/// of one bundle, in sample order, with the parent's column space.
///
/// Built with [`BundleCsr::gather`] into reusable buffers — after the
/// first few bundles the vectors have reached steady capacity and a
/// gather allocates nothing. Row `k` of the stack is a verbatim copy of
/// `a.row(row_ids[k])` (duplicate ids are simply copied twice, matching
/// what the indirect kernels read).
#[derive(Clone, Debug, Default)]
pub struct BundleCsr {
    rows: usize,
    cols: usize,
    /// Row pointer, length `rows + 1` once gathered (empty when fresh).
    indptr: Vec<usize>,
    /// Column indices in the parent's column space.
    indices: Vec<u32>,
    /// Nonzero values, parallel to `indices`.
    values: Vec<f64>,
}

impl BundleCsr {
    /// An empty stack (0 × 0); call [`BundleCsr::gather`] to fill it.
    pub fn new() -> BundleCsr {
        BundleCsr::default()
    }

    /// Gather the given rows of `a` (in order) into this stack, reusing
    /// the existing buffers. The previous contents are discarded.
    pub fn gather(&mut self, a: &Csr, row_ids: &[usize]) {
        self.rows = row_ids.len();
        self.cols = a.cols();
        self.indptr.clear();
        self.indptr.reserve(row_ids.len() + 1);
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
        let nnz: usize = row_ids.iter().map(|&r| a.row_nnz(r)).sum();
        self.indices.reserve(nnz);
        self.values.reserve(nnz);
        for &r in row_ids {
            let (ci, vi) = a.row(r);
            self.indices.extend_from_slice(ci);
            self.values.extend_from_slice(vi);
            self.indptr.push(self.indices.len());
        }
    }

    /// Gathered rows (`q` of the last gather; 0 when fresh).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Parent column count (`n_local`).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored entries across the gathered rows.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// (column indices, values) of gathered row `r`.
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let span = self.indptr[r]..self.indptr[r + 1];
        (&self.indices[span.clone()], &self.values[span])
    }

    /// `out[j] = Y[j, :] · x` — the bundle's forward product `v = Y·x`
    /// (Algorithm 1 line 4). Bit-identical to
    /// [`Csr::spmv_rows`]`(row_ids, x, out)` on the gathered rows: same
    /// products, same accumulation order, read from the packed stack.
    pub fn spmv(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.rows, "bundle spmv out length");
        assert_eq!(x.len(), self.cols, "bundle spmv x length");
        for (j, o) in out.iter_mut().enumerate() {
            let (ci, vi) = self.row(j);
            let mut acc = 0.0;
            for (&c, &v) in ci.iter().zip(vi) {
                acc += v * x[c as usize];
            }
            *o = acc;
        }
    }

    /// `out += Σ_j coeff[j] · Y[j, :]` — the bundle's weight scatter
    /// `x += (η/b)·Yᵀz` (Algorithm 3 line 14). Bit-identical to
    /// [`Csr::t_spmv_rows_acc`] on the gathered rows (including the
    /// zero-coefficient skip).
    pub fn t_spmv_acc(&self, coeff: &[f64], out: &mut [f64]) {
        assert_eq!(coeff.len(), self.rows, "bundle t_spmv coeff length");
        assert_eq!(out.len(), self.cols, "bundle t_spmv out length");
        for (j, &c0) in coeff.iter().enumerate() {
            if c0 == 0.0 {
                continue;
            }
            let (ci, vi) = self.row(j);
            for (&c, &v) in ci.iter().zip(vi) {
                out[c as usize] += c0 * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gram;
    use crate::util::proptest::{check, Config};
    use crate::util::Prng;

    fn bits(x: &[f64]) -> Vec<u64> {
        x.iter().map(|v| v.to_bits()).collect()
    }

    /// Random ids with duplicates allowed — the indirect kernels accept
    /// them, so the gathered ones must reproduce them too.
    fn random_ids(rng: &mut Prng, rows: usize, len: usize) -> Vec<usize> {
        (0..len).map(|_| rng.next_below(rows)).collect()
    }

    #[test]
    fn gather_copies_rows_in_order() {
        let a = Csr::from_triplets(
            3,
            4,
            &[(0, 0, 1.0), (0, 2, 2.0), (1, 2, 3.0), (2, 1, -1.0), (2, 3, 4.0)],
        );
        let mut y = BundleCsr::new();
        y.gather(&a, &[2, 0, 2]);
        assert_eq!(y.rows(), 3);
        assert_eq!(y.cols(), 4);
        assert_eq!(y.nnz(), 6);
        let (c0, v0) = y.row(0);
        assert_eq!((c0, v0), a.row(2));
        let (c1, v1) = y.row(1);
        assert_eq!((c1, v1), a.row(0));
        let (c2, v2) = y.row(2);
        assert_eq!((c2, v2), a.row(2));
    }

    #[test]
    fn gather_empty_batch_is_zero_rows() {
        let mut rng = Prng::new(3);
        let a = Csr::random(5, 7, 2, &mut rng);
        let mut y = BundleCsr::new();
        y.gather(&a, &[]);
        assert_eq!(y.rows(), 0);
        assert_eq!(y.nnz(), 0);
        let x = vec![0.0; 7];
        let mut out: Vec<f64> = vec![];
        y.spmv(&x, &mut out);
        let mut acc = vec![1.0; 7];
        y.t_spmv_acc(&[], &mut acc);
        assert_eq!(acc, vec![1.0; 7]);
    }

    /// Re-gathering into the same scratch must behave exactly like a
    /// fresh gather (the per-rank reuse path).
    #[test]
    fn regather_matches_fresh() {
        let mut rng = Prng::new(11);
        let a = Csr::random(20, 15, 4, &mut rng);
        let ids1 = random_ids(&mut rng, 20, 9);
        let ids2 = random_ids(&mut rng, 20, 5);
        let mut reused = BundleCsr::new();
        reused.gather(&a, &ids1);
        reused.gather(&a, &ids2);
        let mut fresh = BundleCsr::new();
        fresh.gather(&a, &ids2);
        assert_eq!(reused.rows(), fresh.rows());
        assert_eq!(reused.nnz(), fresh.nnz());
        for r in 0..fresh.rows() {
            assert_eq!(reused.row(r), fresh.row(r));
        }
    }

    /// The tentpole contract: gathered spmv / t_spmv / Gram (both
    /// strategies) are **bit-identical** to the indirect kernels, across
    /// random shapes, duplicate ids, and empty batches.
    #[test]
    fn prop_gathered_kernels_bit_identical_to_indirect() {
        check(
            Config { cases: 48, seed: 0xB0D1E },
            "gathered kernels == indirect kernels, bit for bit",
            |rng| {
                let rows = 1 + rng.next_below(30);
                let cols = 1 + rng.next_below(40);
                let a = Csr::random(rows, cols, 1 + rng.next_below(6), rng);
                // Empty batches included (q = 0).
                let q = rng.next_below(13);
                let ids = random_ids(rng, rows, q);
                let x: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
                let coeff: Vec<f64> = (0..q).map(|_| rng.next_gaussian()).collect();
                (a, ids, x, coeff)
            },
            |(a, ids, x, coeff)| {
                let q = ids.len();
                let mut y = BundleCsr::new();
                y.gather(a, ids);

                let mut v_ind = vec![0.0; q];
                a.spmv_rows(ids, x, &mut v_ind);
                let mut v_gat = vec![0.0; q];
                y.spmv(x, &mut v_gat);

                let mut acc_ind = x.clone();
                a.t_spmv_rows_acc(ids, coeff, &mut acc_ind);
                let mut acc_gat = x.clone();
                y.t_spmv_acc(coeff, &mut acc_gat);

                let mut g_ind = vec![0.0; q * q];
                gram::gram_lower(a, ids, &mut g_ind);
                let mut g_merge = vec![0.0; q * q];
                gram::gram_lower_gathered(&y, &mut g_merge);

                let mut scratch_ind = vec![0.0; a.cols()];
                let mut g_scat_ind = vec![0.0; q * q];
                gram::gram_lower_scatter(a, ids, &mut scratch_ind, &mut g_scat_ind);
                let mut scratch_gat = vec![0.0; y.cols()];
                let mut g_scat = vec![0.0; q * q];
                gram::gram_lower_scatter_gathered(&y, &mut scratch_gat, &mut g_scat);

                bits(&v_ind) == bits(&v_gat)
                    && bits(&acc_ind) == bits(&acc_gat)
                    && bits(&g_ind) == bits(&g_merge)
                    && bits(&g_scat_ind) == bits(&g_scat)
            },
        );
    }

    /// Merge and scatter Gram must agree **bitwise** (not just to
    /// tolerance): `GramStrategy` — and therefore `--gram` — can never
    /// move a trajectory.
    #[test]
    fn prop_merge_and_scatter_bitwise_equal() {
        check(
            Config { cases: 48, seed: 0x6B17 },
            "gram merge == gram scatter, bit for bit",
            |rng| {
                let rows = 2 + rng.next_below(24);
                let cols = 1 + rng.next_below(32);
                let a = Csr::random(rows, cols, 1 + rng.next_below(7), rng);
                let q = 1 + rng.next_below(10);
                let ids = random_ids(rng, rows, q);
                (a, ids)
            },
            |(a, ids)| {
                let q = ids.len();
                let mut y = BundleCsr::new();
                y.gather(a, ids);
                let mut merge = vec![0.0; q * q];
                gram::gram_lower_gathered(&y, &mut merge);
                let mut scratch = vec![0.0; y.cols()];
                let mut scat = vec![0.0; q * q];
                gram::gram_lower_scatter_gathered(&y, &mut scratch, &mut scat);
                bits(&merge) == bits(&scat)
            },
        );
    }

    #[test]
    fn auto_resolves_at_the_density_crossover() {
        let eps = 1e-9;
        assert_eq!(
            GramStrategy::Auto.resolve(GRAM_MERGE_MAX_ZBAR - eps),
            GramStrategy::Merge
        );
        assert_eq!(GramStrategy::Auto.resolve(GRAM_MERGE_MAX_ZBAR), GramStrategy::Scatter);
        assert_eq!(GramStrategy::Auto.resolve(0.0), GramStrategy::Merge);
        // Fixed strategies ignore the density.
        for z in [0.0, GRAM_MERGE_MAX_ZBAR, 1e6] {
            assert_eq!(GramStrategy::Merge.resolve(z), GramStrategy::Merge);
            assert_eq!(GramStrategy::Scatter.resolve(z), GramStrategy::Scatter);
        }
    }

    #[test]
    fn names_roundtrip() {
        for g in [GramStrategy::Merge, GramStrategy::Scatter, GramStrategy::Auto] {
            assert_eq!(g.name().parse::<GramStrategy>(), Ok(g));
        }
        assert!("nope".parse::<GramStrategy>().is_err());
    }
}
