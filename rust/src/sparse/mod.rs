//! Sparse-matrix substrate (the role Intel MKL sparse BLAS plays in the
//! paper's C++ implementation).
//!
//! Everything the solvers need is here: CSR storage, SpMV, the transposed
//! SpMV scatter that forms the gradient, batched row gather (sparse and
//! densified), the bundle working-set layer ([`bundle::BundleCsr`] — the
//! materialized `Y` stack the per-bundle kernels run on), the sparse Gram
//! (`syrk`) used by the s-step bundle with its merge/scatter/auto strategy
//! knob ([`bundle::GramStrategy`]), and the nonzero-distribution statistics
//! (`κ`, degree histograms) that drive the partitioning study.

pub mod bundle;
pub mod csr;
pub mod gram;
pub mod stats;

pub use bundle::{BundleCsr, GramStrategy, GRAM_MERGE_MAX_ZBAR};
pub use csr::Csr;
pub use stats::{col_degrees, row_degrees, NnzStats};
