//! Compressed Sparse Row matrices over `f64`.
//!
//! Matches the paper's storage choice (§7: "A is stored in three-array CSR
//! format"). The solvers only ever touch sparse data through this type, so
//! the per-call costs the cost model reasons about (§6.5: inspector
//! overheads, transpose-SpMV scatter) correspond to real code here.

use crate::util::Prng;

/// Three-array CSR sparse matrix, rows × cols, f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Row pointer, length `rows + 1`.
    indptr: Vec<usize>,
    /// Column indices, length nnz; strictly increasing within each row.
    indices: Vec<u32>,
    /// Nonzero values, parallel to `indices`.
    values: Vec<f64>,
}

impl Csr {
    /// Build from triplets `(row, col, value)`. Duplicates are summed;
    /// explicit zeros are kept (they count as stored nonzeros, as in MKL).
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> Self {
        for &(r, c, _) in triplets {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of {rows}x{cols}");
        }
        let mut order: Vec<usize> = (0..triplets.len()).collect();
        order.sort_unstable_by_key(|&i| (triplets[i].0, triplets[i].1));

        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut last: Option<(usize, usize)> = None;
        for &i in &order {
            let (r, c, v) = triplets[i];
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[r + 1] += 1;
                indices.push(c as u32);
                values.push(v);
                last = Some((r, c));
            }
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Build directly from validated CSR arrays.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Self {
        assert_eq!(indptr.len(), rows + 1, "indptr length");
        assert_eq!(indices.len(), values.len(), "indices/values length");
        assert_eq!(*indptr.last().unwrap(), indices.len(), "indptr tail");
        for r in 0..rows {
            assert!(indptr[r] <= indptr[r + 1], "indptr monotonicity at row {r}");
            let row = &indices[indptr[r]..indptr[r + 1]];
            for w in row.windows(2) {
                assert!(w[0] < w[1], "unsorted/duplicate column in row {r}");
            }
            if let Some(&last) = row.last() {
                assert!((last as usize) < cols, "column out of range in row {r}");
            }
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// An `rows × cols` matrix with no nonzeros.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Csr { rows, cols, indptr: vec![0; rows + 1], indices: vec![], values: vec![] }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Mean nonzeros per row (the paper's `z̄`).
    pub fn mean_row_nnz(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows as f64
        }
    }

    /// Raw row pointer.
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }
    /// Raw column indices.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }
    /// Raw values.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// (column indices, values) of row `r`.
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let span = self.indptr[r]..self.indptr[r + 1];
        (&self.indices[span.clone()], &self.values[span])
    }

    /// Number of stored entries in row `r`.
    pub fn row_nnz(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Scale each row `i` by `scale[i]` in place. Used once at load time to
    /// fold the labels in: the paper precomputes `diag(y)·A`.
    pub fn scale_rows(&mut self, scale: &[f64]) {
        assert_eq!(scale.len(), self.rows, "scale length");
        for r in 0..self.rows {
            let s = scale[r];
            for v in &mut self.values[self.indptr[r]..self.indptr[r + 1]] {
                *v *= s;
            }
        }
    }

    /// `out = A·x` (dense x of length `cols`, dense out of length `rows`).
    pub fn spmv(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "spmv x length");
        assert_eq!(out.len(), self.rows, "spmv out length");
        for r in 0..self.rows {
            let mut acc = 0.0;
            for k in self.indptr[r]..self.indptr[r + 1] {
                acc += self.values[k] * x[self.indices[k] as usize];
            }
            out[r] = acc;
        }
    }

    /// SpMV restricted to a set of rows: `out[j] = A[rows[j], :] · x`.
    /// This is the sub-sampled `S_k · diag(y) · A · x` product of
    /// Algorithm 1 line 4 — the forward hot path.
    pub fn spmv_rows(&self, row_ids: &[usize], x: &[f64], out: &mut [f64]) {
        assert_eq!(row_ids.len(), out.len(), "spmv_rows out length");
        assert_eq!(x.len(), self.cols, "spmv_rows x length");
        for (j, &r) in row_ids.iter().enumerate() {
            let mut acc = 0.0;
            for k in self.indptr[r]..self.indptr[r + 1] {
                acc += self.values[k] * x[self.indices[k] as usize];
            }
            out[j] = acc;
        }
    }

    /// Transposed sub-sampled SpMV with scatter-accumulate:
    /// `out += Σ_j coeff[j] · A[rows[j], :]`. This forms the gradient
    /// (Algorithm 1 line 5) and the s-step weight update (Algorithm 3
    /// line 14) without materializing `Aᵀ`.
    pub fn t_spmv_rows_acc(&self, row_ids: &[usize], coeff: &[f64], out: &mut [f64]) {
        assert_eq!(row_ids.len(), coeff.len(), "t_spmv coeff length");
        assert_eq!(out.len(), self.cols, "t_spmv out length");
        for (j, &r) in row_ids.iter().enumerate() {
            let c = coeff[j];
            if c == 0.0 {
                continue;
            }
            for k in self.indptr[r]..self.indptr[r + 1] {
                out[self.indices[k] as usize] += c * self.values[k];
            }
        }
    }

    /// Extract the sub-matrix of the given rows (in the given order) as a new
    /// CSR. Used to build per-rank local blocks after 2D partitioning.
    pub fn gather_rows(&self, row_ids: &[usize]) -> Csr {
        let mut indptr = Vec::with_capacity(row_ids.len() + 1);
        indptr.push(0usize);
        let nnz: usize = row_ids.iter().map(|&r| self.row_nnz(r)).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for &r in row_ids {
            let (ci, cv) = self.row(r);
            indices.extend_from_slice(ci);
            values.extend_from_slice(cv);
            indptr.push(indices.len());
        }
        Csr { rows: row_ids.len(), cols: self.cols, indptr, indices, values }
    }

    /// Keep only the columns selected by `col_map` (old → Some(new)),
    /// producing a matrix with `new_cols` columns. Column order within a row
    /// follows the new indices (caller guarantees `col_map` is monotone-
    /// compatible or accepts re-sorting; we always re-sort for safety).
    pub fn select_columns(&self, col_map: &[Option<u32>], new_cols: usize) -> Csr {
        assert_eq!(col_map.len(), self.cols, "col_map length");
        let mut indptr = Vec::with_capacity(self.rows + 1);
        indptr.push(0usize);
        let mut indices: Vec<u32> = Vec::new();
        let mut values: Vec<f64> = Vec::new();
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for r in 0..self.rows {
            scratch.clear();
            for k in self.indptr[r]..self.indptr[r + 1] {
                if let Some(nc) = col_map[self.indices[k] as usize] {
                    debug_assert!((nc as usize) < new_cols);
                    scratch.push((nc, self.values[k]));
                }
            }
            scratch.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in &scratch {
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        Csr { rows: self.rows, cols: new_cols, indptr, indices, values }
    }

    /// Densify the given rows into a row-major `row_ids.len() × cols` buffer
    /// (used to feed the dense XLA kernels; `out` must be zeroed or will be
    /// overwritten fully).
    pub fn densify_rows(&self, row_ids: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), row_ids.len() * self.cols, "densify out length");
        out.fill(0.0);
        for (j, &r) in row_ids.iter().enumerate() {
            let base = j * self.cols;
            for k in self.indptr[r]..self.indptr[r + 1] {
                out[base + self.indices[k] as usize] = self.values[k];
            }
        }
    }

    /// Full dense copy (tests / tiny matrices only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                out[r * self.cols + self.indices[k] as usize] = self.values[k];
            }
        }
        out
    }

    /// Explicit transpose as CSR (used by tests as an oracle for
    /// `t_spmv_rows_acc`; not on any hot path).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for c in 0..self.cols {
            counts[c + 1] += counts[c];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f64; self.nnz()];
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[k] as usize;
                let dst = cursor[c];
                cursor[c] += 1;
                indices[dst] = r as u32;
                values[dst] = self.values[k];
            }
        }
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// A random sparse matrix for tests: each row draws `row_nnz` distinct
    /// columns uniformly, values standard normal.
    pub fn random(rows: usize, cols: usize, row_nnz: usize, rng: &mut Prng) -> Csr {
        let mut triplets = Vec::with_capacity(rows * row_nnz);
        for r in 0..rows {
            for c in rng.sample_distinct(cols, row_nnz.min(cols)) {
                triplets.push((r, c, rng.next_gaussian()));
            }
        }
        Csr::from_triplets(rows, cols, &triplets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    fn small() -> Csr {
        // [1 0 2]
        // [0 3 0]
        Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)])
    }

    #[test]
    fn triplets_roundtrip_dense() {
        let a = small();
        assert_eq!(a.to_dense(), vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.row_nnz(0), 2);
    }

    #[test]
    fn duplicate_triplets_sum() {
        let a = Csr::from_triplets(1, 2, &[(0, 1, 1.0), (0, 1, 2.5)]);
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.to_dense(), vec![0.0, 3.5]);
    }

    #[test]
    fn spmv_matches_dense() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut out = [0.0; 2];
        a.spmv(&x, &mut out);
        assert_eq!(out, [7.0, 6.0]);
    }

    #[test]
    fn spmv_rows_subsample() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut out = [0.0; 3];
        a.spmv_rows(&[1, 0, 1], &x, &mut out);
        assert_eq!(out, [6.0, 7.0, 6.0]);
    }

    #[test]
    fn t_spmv_accumulates() {
        let a = small();
        let mut out = vec![10.0, 0.0, 0.0];
        a.t_spmv_rows_acc(&[0, 1], &[2.0, -1.0], &mut out);
        // 10 + 2*1 = 12 ; -1*3 = -3 ; 2*2 = 4
        assert_eq!(out, vec![12.0, -3.0, 4.0]);
    }

    #[test]
    fn gather_rows_order_preserved() {
        let a = small();
        let g = a.gather_rows(&[1, 0]);
        assert_eq!(g.to_dense(), vec![0.0, 3.0, 0.0, 1.0, 0.0, 2.0]);
    }

    #[test]
    fn select_columns_drops_and_renames() {
        let a = small();
        // Keep columns {2, 0} -> new ids {0 -> 1, 2 -> 0}? map: old0->1, old1->None, old2->0
        let map = vec![Some(1u32), None, Some(0u32)];
        let s = a.select_columns(&map, 2);
        assert_eq!(s.to_dense(), vec![2.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn densify_rows_matches_dense() {
        let a = small();
        let mut out = vec![f64::NAN; 2 * 3];
        a.densify_rows(&[0, 1], &mut out);
        assert_eq!(out, a.to_dense());
    }

    #[test]
    fn scale_rows_folds_labels() {
        let mut a = small();
        a.scale_rows(&[-1.0, 2.0]);
        assert_eq!(a.to_dense(), vec![-1.0, 0.0, -2.0, 0.0, 6.0, 0.0]);
    }

    #[test]
    fn transpose_is_involution_and_oracle() {
        let mut rng = Prng::new(17);
        let a = Csr::random(20, 15, 4, &mut rng);
        let t = a.transpose();
        assert_eq!(t.rows(), 15);
        assert_eq!(t.transpose().to_dense(), a.to_dense());
    }

    #[test]
    fn prop_tspmv_matches_transpose_oracle() {
        check(
            Config { cases: 32, seed: 0xA11CE },
            "t_spmv == transpose.spmv",
            |rng| {
                let rows = 1 + rng.next_below(30);
                let cols = 1 + rng.next_below(40);
                let nnz = 1 + rng.next_below(6);
                let a = Csr::random(rows, cols, nnz, rng);
                let b = 1 + rng.next_below(rows);
                let row_ids: Vec<usize> = (0..b).map(|_| rng.next_below(rows)).collect();
                let coeff: Vec<f64> = (0..b).map(|_| rng.next_gaussian()).collect();
                (a, row_ids, coeff)
            },
            |(a, row_ids, coeff)| {
                let mut got = vec![0.0; a.cols()];
                a.t_spmv_rows_acc(row_ids, coeff, &mut got);
                // Oracle: dense scatter of coeff into an m-vector, then Aᵀ·u.
                let mut u = vec![0.0; a.rows()];
                for (j, &r) in row_ids.iter().enumerate() {
                    u[r] += coeff[j];
                }
                let t = a.transpose();
                let mut want = vec![0.0; a.cols()];
                t.spmv(&u, &mut want);
                got.iter().zip(&want).all(|(g, w)| (g - w).abs() <= 1e-9 * (1.0 + w.abs()))
            },
        );
    }

    #[test]
    fn prop_spmv_rows_matches_gather() {
        check(
            Config { cases: 32, seed: 0xB0B },
            "spmv_rows == gather_rows.spmv",
            |rng| {
                let rows = 1 + rng.next_below(25);
                let cols = 1 + rng.next_below(25);
                let a = Csr::random(rows, cols, 1 + rng.next_below(5), rng);
                let ids: Vec<usize> =
                    (0..1 + rng.next_below(12)).map(|_| rng.next_below(rows)).collect();
                let x: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
                (a, ids, x)
            },
            |(a, ids, x)| {
                let mut got = vec![0.0; ids.len()];
                a.spmv_rows(ids, x, &mut got);
                let g = a.gather_rows(ids);
                let mut want = vec![0.0; ids.len()];
                g.spmv(x, &mut want);
                got.iter().zip(&want).all(|(a, b)| (a - b).abs() < 1e-12)
            },
        );
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn triplet_bounds_checked() {
        let _ = Csr::from_triplets(2, 2, &[(2, 0, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "unsorted")]
    fn from_parts_rejects_unsorted() {
        let _ = Csr::from_parts(1, 3, vec![0, 2], vec![2, 1], vec![1.0, 2.0]);
    }
}
