//! Sparse Gram computation — the role `mkl_sparse_syrkd` plays in the
//! paper's s-step bundle (Algorithm 3 line 6: `G = tril(Y·Yᵀ)`).
//!
//! `Y` is the `sb × n_local` stack of sampled, label-scaled rows; `G` is the
//! small dense lower-triangular Gram whose blocks correct the deferred
//! updates. Since the bundle working-set layer landed, the solver hot path
//! runs on a **materialized** `Y` ([`BundleCsr`], gathered once per bundle
//! into cache-contiguous per-rank scratch) rather than chasing `row_ids`
//! indirection into the parent block — the Gram is the kernel that gains
//! most, because it re-reads every sampled row `O(q)` times and those reads
//! now stream a packed stack that fits a faster cache tier. Two strategies
//! (selected by [`GramStrategy`](super::bundle::GramStrategy), threaded
//! from `RunOpts::gram` / `--gram`):
//!
//! * [`gram_lower_gathered`] (**merge**) — row-pair sparse dot products via
//!   two-pointer merges (`O(q² · z̄)` comparisons with early exit; wins on
//!   short rows, no dense scratch traffic).
//! * [`gram_lower_scatter_gathered`] (**scatter**) — scatter/gather over a
//!   dense accumulator of length `n_local`: one branch-free multiply-add
//!   per stored entry (the `mkl_sparse_syrkd` executor structure whose
//!   per-call `O(n_local)` floor the paper measures in §6.5; wins on
//!   denser rows).
//!
//! The two strategies are **bit-identical** to each other (scatter's extra
//! terms are exact `+0.0`s against an accumulator that can never be
//! `-0.0`; a tested property in [`super::bundle`]), and each is
//! bit-identical to its indirect seed twin ([`gram_lower`] /
//! [`gram_lower_scatter`], kept for the reference solver, the ablation
//! bench baselines, and as test oracles) — so the strategy knob moves wall
//! time, never trajectories.

use super::bundle::BundleCsr;
use super::csr::Csr;

/// Dense lower-triangular Gram `G[i*q + j] = rowᵢ · rowⱼ` for `j ≤ i`,
/// where row k of `Y` is `A[row_ids[k], :]`; `q = row_ids.len()`.
/// Upper triangle is left as zero (the s-step correction only reads
/// `TRIL`, matching Algorithm 3).
pub fn gram_lower(a: &Csr, row_ids: &[usize], out: &mut [f64]) {
    let q = row_ids.len();
    assert_eq!(out.len(), q * q, "gram out size");
    out.fill(0.0);
    for i in 0..q {
        let (ci, vi) = a.row(row_ids[i]);
        for j in 0..=i {
            let (cj, vj) = a.row(row_ids[j]);
            out[i * q + j] = sparse_dot(ci, vi, cj, vj);
        }
    }
}

/// Merge-based sparse dot product of two sorted index/value rows.
#[inline]
pub fn sparse_dot(ci: &[u32], vi: &[f64], cj: &[u32], vj: &[f64]) -> f64 {
    let (mut x, mut y) = (0usize, 0usize);
    let mut acc = 0.0;
    while x < ci.len() && y < cj.len() {
        match ci[x].cmp(&cj[y]) {
            std::cmp::Ordering::Less => x += 1,
            std::cmp::Ordering::Greater => y += 1,
            std::cmp::Ordering::Equal => {
                acc += vi[x] * vj[y];
                x += 1;
                y += 1;
            }
        }
    }
    acc
}

/// Scatter-based Gram: densifies one row at a time into a scratch vector of
/// length `a.cols()` and gathers dot products against the remaining rows.
/// `scratch` must have length `a.cols()`; it is cleaned (not re-zeroed in
/// full) after each row, so repeated calls stay `O(nnz)` amortized — this is
/// the optimization `mkl_sparse_syrkd`'s executor performs, and its
/// `O(n_local)` first-touch cost is what the paper's §6.5 refinement models.
pub fn gram_lower_scatter(a: &Csr, row_ids: &[usize], scratch: &mut [f64], out: &mut [f64]) {
    let q = row_ids.len();
    assert_eq!(out.len(), q * q, "gram out size");
    assert_eq!(scratch.len(), a.cols(), "scratch size");
    out.fill(0.0);
    for i in 0..q {
        let (ci, vi) = a.row(row_ids[i]);
        // Scatter row i.
        for (k, &c) in ci.iter().enumerate() {
            scratch[c as usize] = vi[k];
        }
        // Diagonal.
        out[i * q + i] = vi.iter().map(|v| v * v).sum();
        // Gather against rows j < i.
        for j in 0..i {
            let (cj, vj) = a.row(row_ids[j]);
            let mut acc = 0.0;
            for (k, &c) in cj.iter().enumerate() {
                acc += vj[k] * scratch[c as usize];
            }
            out[i * q + j] = acc;
        }
        // Clean scratch (only the touched entries).
        for &c in ci {
            scratch[c as usize] = 0.0;
        }
    }
}

/// Merge-strategy Gram over a materialized bundle stack: dense
/// lower-triangular `G[i*q + j] = Y[i,:] · Y[j,:]` for `j ≤ i`, upper
/// triangle left zero. Bit-identical to [`gram_lower`]`(a, row_ids, out)`
/// when `y` was gathered from `(a, row_ids)` — same dot products, same
/// merge order, read from the packed stack.
pub fn gram_lower_gathered(y: &BundleCsr, out: &mut [f64]) {
    let q = y.rows();
    assert_eq!(out.len(), q * q, "gram out size");
    out.fill(0.0);
    for i in 0..q {
        let (ci, vi) = y.row(i);
        for j in 0..=i {
            let (cj, vj) = y.row(j);
            out[i * q + j] = sparse_dot(ci, vi, cj, vj);
        }
    }
}

/// Scatter-strategy Gram over a materialized bundle stack: densifies one
/// gathered row at a time into `scratch` (length `y.cols()`, cleaned —
/// not re-zeroed in full — after each row, so repeated calls stay
/// `O(nnz)` amortized) and gathers dot products against the earlier rows.
/// Bit-identical to [`gram_lower_scatter`] on the same rows, and to the
/// merge strategy (see the module docs).
pub fn gram_lower_scatter_gathered(y: &BundleCsr, scratch: &mut [f64], out: &mut [f64]) {
    let q = y.rows();
    assert_eq!(out.len(), q * q, "gram out size");
    assert_eq!(scratch.len(), y.cols(), "scratch size");
    out.fill(0.0);
    for i in 0..q {
        let (ci, vi) = y.row(i);
        // Scatter row i.
        for (&c, &v) in ci.iter().zip(vi) {
            scratch[c as usize] = v;
        }
        // Diagonal.
        out[i * q + i] = vi.iter().map(|v| v * v).sum();
        // Gather against rows j < i.
        for j in 0..i {
            let (cj, vj) = y.row(j);
            let mut acc = 0.0;
            for (&c, &v) in cj.iter().zip(vj) {
                acc += v * scratch[c as usize];
            }
            out[i * q + j] = acc;
        }
        // Clean scratch (only the touched entries).
        for &c in ci {
            scratch[c as usize] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::Prng;

    fn dense_gram_lower(a: &Csr, row_ids: &[usize]) -> Vec<f64> {
        let q = row_ids.len();
        let n = a.cols();
        let d = a.to_dense();
        let mut out = vec![0.0; q * q];
        for i in 0..q {
            for j in 0..=i {
                let (ri, rj) = (row_ids[i], row_ids[j]);
                out[i * q + j] = (0..n).map(|c| d[ri * n + c] * d[rj * n + c]).sum();
            }
        }
        out
    }

    #[test]
    fn gram_small_exact() {
        let a = Csr::from_triplets(
            3,
            4,
            &[(0, 0, 1.0), (0, 2, 2.0), (1, 2, 3.0), (2, 1, -1.0), (2, 3, 4.0)],
        );
        let ids = [0, 1, 2];
        let mut g = vec![0.0; 9];
        gram_lower(&a, &ids, &mut g);
        assert_eq!(g, dense_gram_lower(&a, &ids));
        // Known entries: G[1][0] = rows 0·1 = 2*3 = 6 ; G[2][*] = 0 overlap.
        assert_eq!(g[3], 6.0);
        assert_eq!(g[6], 0.0);
        assert_eq!(g[7], 0.0);
        // Upper triangle untouched (zero).
        assert_eq!(g[1], 0.0);
        assert_eq!(g[2], 0.0);
    }

    #[test]
    fn prop_merge_and_scatter_agree_with_dense() {
        check(
            Config { cases: 40, seed: 0x6A5 },
            "gram merge == scatter == dense",
            |rng| {
                let rows = 2 + rng.next_below(20);
                let cols = 1 + rng.next_below(30);
                let a = Csr::random(rows, cols, 1 + rng.next_below(6), rng);
                let q = 1 + rng.next_below(8.min(rows));
                let ids: Vec<usize> = (0..q).map(|_| rng.next_below(rows)).collect();
                (a, ids)
            },
            |(a, ids)| {
                let q = ids.len();
                let want = dense_gram_lower(a, ids);
                let mut merge = vec![0.0; q * q];
                gram_lower(a, ids, &mut merge);
                let mut scratch = vec![0.0; a.cols()];
                let mut scat = vec![0.0; q * q];
                gram_lower_scatter(a, ids, &mut scratch, &mut scat);
                let close = |x: &[f64], y: &[f64]| {
                    x.iter().zip(y).all(|(a, b)| (a - b).abs() <= 1e-9 * (1.0 + b.abs()))
                };
                close(&merge, &want) && close(&scat, &want)
            },
        );
    }

    #[test]
    fn scatter_scratch_stays_clean() {
        let mut rng = Prng::new(23);
        let a = Csr::random(10, 20, 4, &mut rng);
        let mut scratch = vec![0.0; 20];
        let mut g = vec![0.0; 16];
        gram_lower_scatter(&a, &[0, 3, 5, 7], &mut scratch, &mut g);
        assert!(scratch.iter().all(|&v| v == 0.0), "scratch leaked: {scratch:?}");
    }

    #[test]
    fn repeated_rows_give_symmetric_diagonal_blocks() {
        let mut rng = Prng::new(29);
        let a = Csr::random(6, 12, 3, &mut rng);
        let mut g = vec![0.0; 4];
        gram_lower(&a, &[2, 2], &mut g);
        // G = [‖r2‖² 0; ‖r2‖² ‖r2‖²]
        assert!((g[0] - g[3]).abs() < 1e-12);
        assert!((g[2] - g[0]).abs() < 1e-12);
    }
}
