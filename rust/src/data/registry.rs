//! The dataset registry: matched profiles of the paper's Table 6 suite.
//!
//! Each profile records the *paper-scale* shape and the *repro-scale* shape
//! actually generated here (≈1/32 linear scale by default, adjustable with
//! a scale factor). The column-skew exponents are chosen so the generated
//! κ (per-rank nnz imbalance under the `rows` partitioner) falls in the
//! band the paper measures: url κ≈34 at p_c=64, news20 κ≈19, rcv1 κ≈1.6.

use super::{synth, Dataset};
use crate::util::Prng;

/// Which paper dataset a profile mirrors.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetSpec {
    /// url: 2.4M × 3.2M, z̄=116, extreme column skew. The HybridSGD
    /// headline dataset (53× over FedAvg).
    UrlLike,
    /// news20: 20K × 1.36M, z̄=455, moderate/extreme skew, large z̄.
    News20Like,
    /// rcv1: 20K × 47K, z̄=74, mild skew, small n.
    Rcv1Like,
    /// epsilon: 400K × 2K dense. FedAvg's winning regime.
    EpsilonLike,
    /// Uniform synthetic (Fig. 7 right, Table 4 "synthetic" row).
    SyntheticUniform,
}

impl DatasetSpec {
    /// All registry entries in paper order.
    pub fn all() -> [DatasetSpec; 5] {
        [
            DatasetSpec::Rcv1Like,
            DatasetSpec::News20Like,
            DatasetSpec::UrlLike,
            DatasetSpec::EpsilonLike,
            DatasetSpec::SyntheticUniform,
        ]
    }

    /// Canonical CLI/wire name — the first alias `FromStr` accepts, so
    /// serve-protocol frames and spool records round-trip through it.
    pub fn cli_name(self) -> &'static str {
        match self {
            DatasetSpec::UrlLike => "url",
            DatasetSpec::News20Like => "news20",
            DatasetSpec::Rcv1Like => "rcv1",
            DatasetSpec::EpsilonLike => "epsilon",
            DatasetSpec::SyntheticUniform => "synthetic",
        }
    }

    /// The profile for this spec.
    pub fn profile(self) -> DatasetProfile {
        match self {
            DatasetSpec::UrlLike => DatasetProfile {
                name: "url-like",
                paper_m: 2_396_130,
                paper_n: 3_231_961,
                paper_zbar: 116,
                // n is scaled much less aggressively than m (√scale, see
                // `generate_scaled`): the paper's url regime is defined by
                // the dimensionless comparisons n vs the fixed Gram
                // payload sb(sb+1)/2 and the §6.3 balance (s−1)sb²τp_c vs
                // 2n — shrinking n linearly with m would silently move the
                // dataset out of the sync-BW regime that produces the 53×
                // headline.
                m: 24_576,
                n: 405_504, // = 64·6336 = 1024·396: clean splits to p_c=1024
                zbar: 64,
                skew_alpha: 1.05,
                dense: false,
            },
            DatasetSpec::News20Like => DatasetProfile {
                name: "news20-like",
                paper_m: 19_996,
                paper_n: 1_355_191,
                paper_zbar: 455,
                m: 16_384,
                n: 344_064, // = 64·5376; n ≫ Gram payload, as at paper scale
                zbar: 112,
                skew_alpha: 0.95,
                dense: false,
            },
            DatasetSpec::Rcv1Like => DatasetProfile {
                name: "rcv1-like",
                paper_m: 20_242,
                paper_n: 47_236,
                paper_zbar: 74,
                m: 16_384,
                n: 47_104, // ≈ paper n (= 64·736): rcv1 is small enough not to shrink
                zbar: 48,
                skew_alpha: 0.45,
                dense: false,
            },
            DatasetSpec::EpsilonLike => DatasetProfile {
                name: "epsilon-like",
                paper_m: 400_000,
                paper_n: 2_000,
                paper_zbar: 2_000,
                m: 16_384,
                n: 512,
                zbar: 512,
                skew_alpha: 0.0,
                dense: true,
            },
            DatasetSpec::SyntheticUniform => DatasetProfile {
                name: "synthetic-uniform",
                paper_m: 1 << 21,
                paper_n: 3_145_728,
                paper_zbar: 12_583, // density 0.4% of 3.15M
                m: 32_768,
                n: 98_304,
                zbar: 96,
                skew_alpha: 0.0,
                dense: false,
            },
        }
    }
}

crate::impl_enum_from_str!(DatasetSpec, "dataset",
    ("url" | "url-like" => DatasetSpec::UrlLike),
    ("news20" | "news20-like" => DatasetSpec::News20Like),
    ("rcv1" | "rcv1-like" => DatasetSpec::Rcv1Like),
    ("epsilon" | "epsilon-like" => DatasetSpec::EpsilonLike),
    ("synthetic" | "uniform" => DatasetSpec::SyntheticUniform),
);

/// Shape parameters of one dataset profile (paper-scale + repro-scale).
#[derive(Clone, Copy, Debug)]
pub struct DatasetProfile {
    /// Display name, e.g. `url-like`.
    pub name: &'static str,
    /// Paper-scale rows (Table 6).
    pub paper_m: usize,
    /// Paper-scale features (Table 6).
    pub paper_n: usize,
    /// Paper-scale mean nnz/row (Table 6).
    pub paper_zbar: usize,
    /// Repro-scale rows.
    pub m: usize,
    /// Repro-scale features.
    pub n: usize,
    /// Repro-scale mean nnz/row.
    pub zbar: usize,
    /// Column-skew exponent of the generator (0 = uniform).
    pub skew_alpha: f64,
    /// Fully dense (epsilon-like)?
    pub dense: bool,
}

impl DatasetProfile {
    /// Generate the dataset at `scale` × the repro shape (scale 1.0 default;
    /// the experiment drivers use < 1.0 for the quick CI paths).
    /// `m` scales linearly but `n` scales by **√scale**: the communication
    /// regimes the paper's evaluation distinguishes are set by `n` relative
    /// to the (scale-invariant) Gram payload and batch sizes, so `n` must
    /// shrink far more gently than the sample count. `z̄` is held fixed.
    pub fn generate_scaled(&self, scale: f64, seed: u64) -> Dataset {
        assert!(scale > 0.0, "scale must be positive");
        let m = ((self.m as f64 * scale) as usize).max(64);
        let n = ((self.n as f64 * scale.sqrt()) as usize).max(32);
        let mut rng = Prng::new(seed ^ hash_name(self.name));
        if self.dense {
            let n = n.min(4096);
            synth::dense(self.name, m, n, &mut rng)
        } else {
            synth::sparse_skewed(self.name, m, n, self.zbar.min(n), self.skew_alpha, &mut rng)
        }
    }

    /// Generate at the default repro scale.
    pub fn generate(&self, seed: u64) -> Dataset {
        self.generate_scaled(1.0, seed)
    }

    /// Weight-vector footprint in bytes (`n·w`) at repro scale — the
    /// quantity the topology rule's cache term compares to `R · L_cap`.
    pub fn weight_bytes(&self) -> usize {
        self.n * crate::WORD_BYTES
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, stable across runs (DefaultHasher is not guaranteed stable).
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::NnzStats;

    #[test]
    fn profiles_parse_by_name() {
        assert_eq!("url".parse::<DatasetSpec>(), Ok(DatasetSpec::UrlLike));
        assert_eq!("rcv1-like".parse::<DatasetSpec>(), Ok(DatasetSpec::Rcv1Like));
        assert!("nope".parse::<DatasetSpec>().is_err());
    }

    #[test]
    fn small_scale_generation_matches_profile() {
        for spec in DatasetSpec::all() {
            let p = spec.profile();
            let d = p.generate_scaled(0.02, 42);
            assert!(d.m() >= 64, "{}: m={}", p.name, d.m());
            assert!(d.n() >= 32);
            if !p.dense {
                assert!(
                    (d.zbar() - p.zbar.min(d.n()) as f64).abs() < 1.0,
                    "{}: zbar={} want {}",
                    p.name,
                    d.zbar(),
                    p.zbar
                );
            }
        }
    }

    #[test]
    fn url_like_is_most_skewed() {
        let url = DatasetSpec::UrlLike.profile().generate_scaled(0.03, 7);
        let rcv1 = DatasetSpec::Rcv1Like.profile().generate_scaled(0.03, 7);
        let (su, sr) = (NnzStats::of(&url.a), NnzStats::of(&rcv1.a));
        assert!(
            su.col_gini > sr.col_gini,
            "url gini={} rcv1 gini={}",
            su.col_gini,
            sr.col_gini
        );
    }

    #[test]
    fn generation_deterministic() {
        let p = DatasetSpec::Rcv1Like.profile();
        let a = p.generate_scaled(0.01, 3);
        let b = p.generate_scaled(0.01, 3);
        assert_eq!(a.a, b.a);
    }
}
