//! LIBSVM / SVMlight sparse-format reader and writer.
//!
//! Format: one sample per line, `label idx:val idx:val ...`, indices
//! 1-based, `#` comments allowed. The paper's datasets (rcv1, news20, url,
//! epsilon) ship in this format from the LIBSVM repository [7]; with the
//! real files on disk this loader replaces the synthetic profiles.

use super::Dataset;
use crate::sparse::Csr;
use crate::util::error::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Parse LIBSVM text. Labels are normalized to ±1: positive labels
/// (including `+1`, `1`, `2`...) map to +1.0, non-positive to −1.0
/// (LIBSVM binary sets use either {+1,−1} or {1,2} conventions).
/// `n_hint` optionally forces the feature count (otherwise max index).
pub fn parse(text: &str, name: &str, n_hint: Option<usize>) -> Result<Dataset> {
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    let mut y = Vec::new();
    let mut max_col = 0usize;
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            Some(pos) => &raw[..pos],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let row = y.len();
        y.push(if label > 0.0 { 1.0 } else { -1.0 });
        let mut prev_idx = 0usize;
        for tok in parts {
            let (idx_s, val_s) = tok
                .split_once(':')
                .with_context(|| format!("line {}: expected idx:val, got '{tok}'", lineno + 1))?;
            let idx: usize =
                idx_s.parse().with_context(|| format!("line {}: bad index", lineno + 1))?;
            let val: f64 =
                val_s.parse().with_context(|| format!("line {}: bad value", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: LIBSVM indices are 1-based, got 0", lineno + 1);
            }
            if idx <= prev_idx {
                bail!("line {}: indices must be strictly increasing", lineno + 1);
            }
            prev_idx = idx;
            max_col = max_col.max(idx);
            triplets.push((row, idx - 1, val));
        }
    }
    let n = match n_hint {
        Some(n) => {
            if max_col > n {
                bail!("n_hint {n} smaller than max feature index {max_col}");
            }
            n
        }
        None => max_col,
    };
    let a = Csr::from_triplets(y.len(), n, &triplets);
    Ok(Dataset { name: name.to_string(), a, y })
}

/// Read a LIBSVM file from disk.
pub fn read<P: AsRef<Path>>(path: P, n_hint: Option<usize>) -> Result<Dataset> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let name = path
        .as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "libsvm".into());
    parse(&text, &name, n_hint)
}

/// Serialize a dataset to LIBSVM text (1-based indices; floats use the
/// shortest representation that round-trips, so write→read is lossless).
pub fn to_string(ds: &Dataset) -> String {
    let mut out = String::new();
    for r in 0..ds.m() {
        let label = if ds.y[r] > 0.0 { "+1" } else { "-1" };
        out.push_str(label);
        let (ci, cv) = ds.a.row(r);
        for (k, &c) in ci.iter().enumerate() {
            out.push_str(&format!(" {}:{}", c + 1, fmt_g(cv[k])));
        }
        out.push('\n');
    }
    out
}

/// Write a dataset to a LIBSVM file on disk.
pub fn write_file<P: AsRef<Path>>(ds: &Dataset, path: P) -> Result<()> {
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    f.write_all(to_string(ds).as_bytes())?;
    Ok(())
}

fn fmt_g(v: f64) -> String {
    // Shortest representation that round-trips.
    let s = format!("{v}");
    if s.parse::<f64>() == Ok(v) {
        s
    } else {
        format!("{v:.17e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::Prng;

    #[test]
    fn parse_basic() {
        let d = parse("+1 1:0.5 3:2\n-1 2:1.5 # trailing\n\n# comment\n1 1:1\n", "t", None)
            .unwrap();
        assert_eq!(d.m(), 3);
        assert_eq!(d.n(), 3);
        assert_eq!(d.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(d.a.to_dense(), vec![0.5, 0.0, 2.0, 0.0, 1.5, 0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn parse_label_conventions() {
        // {1,2} convention: 2 is positive, and "0" maps negative.
        let d = parse("2 1:1\n1 1:1\n0 1:1\n", "t", None).unwrap();
        assert_eq!(d.y, vec![1.0, 1.0, -1.0]);
    }

    #[test]
    fn parse_rejects_zero_index() {
        assert!(parse("+1 0:1\n", "t", None).is_err());
    }

    #[test]
    fn parse_rejects_unsorted() {
        assert!(parse("+1 3:1 2:1\n", "t", None).is_err());
    }

    #[test]
    fn parse_respects_n_hint() {
        let d = parse("+1 2:1\n", "t", Some(10)).unwrap();
        assert_eq!(d.n(), 10);
        assert!(parse("+1 20:1\n", "t", Some(10)).is_err());
    }

    #[test]
    fn roundtrip_synthetic() {
        let mut rng = Prng::new(7);
        let ds = synth::sparse_skewed("rt", 30, 20, 4, 0.7, &mut rng);
        let text = to_string(&ds);
        let back = parse(&text, "rt", Some(20)).unwrap();
        assert_eq!(back.m(), ds.m());
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.y, ds.y);
        let (da, db) = (ds.a.to_dense(), back.a.to_dense());
        for (x, y) in da.iter().zip(&db) {
            assert!((x - y).abs() < 1e-15);
        }
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Prng::new(8);
        let ds = synth::sparse_skewed("file", 10, 8, 3, 0.0, &mut rng);
        let path = std::env::temp_dir().join(format!("libsvm_test_{}.txt", std::process::id()));
        write_file(&ds, &path).unwrap();
        let back = read(&path, Some(8)).unwrap();
        assert_eq!(back.m(), 10);
        std::fs::remove_file(path).unwrap();
    }
}
