//! Synthetic dataset generators.
//!
//! Three families, matching the data regimes the paper evaluates:
//!
//! * [`sparse_skewed`] — each row draws `z̄` distinct columns from a
//!   power-law column distribution `P(c) ∝ (c+1)^(−α)` (exactly the
//!   generator of the paper's Fig. 3 skew sweep; `α = 0` uniform, `α = 1`
//!   Zipf). This produces the heavy-tailed nonzero-per-column histograms
//!   that drive the partitioning study.
//! * [`sparse_uniform`] — `α = 0` shorthand, the paper's Fig. 7 (right) and
//!   Table 4 "synthetic" dataset.
//! * [`dense`] — fully dense Gaussian features (epsilon-like).
//!
//! Labels come from a *planted model*: a ground-truth weight vector `x★`
//! with Gaussian entries produces `y = sign(A·x★)` and a fraction
//! `label_noise` of labels is flipped. Convergence behaviour is therefore
//! real (the optimum exists and SGD finds it), not mocked — a requirement
//! for the time-to-target-loss experiments (Table 11).

use super::Dataset;
use crate::sparse::Csr;
use crate::util::{Prng, Zipf};

/// Fraction of labels flipped by default (keeps the Bayes loss away from 0
/// so target-loss thresholds behave like the paper's real datasets).
pub const DEFAULT_LABEL_NOISE: f64 = 0.05;

/// Sparse dataset with power-law column skew.
///
/// * `m` samples × `n` features, exactly `zbar` nonzeros per row
///   (capped at `n`), values N(0, 1/√z̄) so row norms are O(1).
/// * `alpha` is the column-skew exponent of Fig. 3.
pub fn sparse_skewed(
    name: &str,
    m: usize,
    n: usize,
    zbar: usize,
    alpha: f64,
    rng: &mut Prng,
) -> Dataset {
    let zipf = Zipf::new(n, alpha);
    let z = zbar.min(n);
    let scale = 1.0 / (z as f64).sqrt();
    let mut indptr = Vec::with_capacity(m + 1);
    indptr.push(0usize);
    let mut indices: Vec<u32> = Vec::with_capacity(m * z);
    let mut values: Vec<f64> = Vec::with_capacity(m * z);
    let mut row_cols: Vec<u32> = Vec::with_capacity(z);
    for _ in 0..m {
        row_cols.clear();
        // Draw distinct columns from the skewed law by rejection; for very
        // skewed heads the same column repeats, so bound the attempts and
        // fall back to uniform fill-in (keeps z̄ exact).
        let mut attempts = 0;
        while row_cols.len() < z && attempts < z * 30 {
            let c = zipf.sample(rng) as u32;
            if !row_cols.contains(&c) {
                row_cols.push(c);
            }
            attempts += 1;
        }
        while row_cols.len() < z {
            let c = rng.next_below(n) as u32;
            if !row_cols.contains(&c) {
                row_cols.push(c);
            }
        }
        row_cols.sort_unstable();
        for &c in row_cols.iter() {
            indices.push(c);
            values.push(rng.next_gaussian() * scale);
        }
        indptr.push(indices.len());
    }
    let a = Csr::from_parts(m, n, indptr, indices, values);
    let y = planted_labels(&a, DEFAULT_LABEL_NOISE, rng);
    Dataset { name: name.to_string(), a, y }
}

/// Sparse dataset with uniform column distribution (`alpha = 0`).
pub fn sparse_uniform(name: &str, m: usize, n: usize, zbar: usize, rng: &mut Prng) -> Dataset {
    sparse_skewed(name, m, n, zbar, 0.0, rng)
}

/// Dense dataset (epsilon-like): every entry N(0, 1/√n).
pub fn dense(name: &str, m: usize, n: usize, rng: &mut Prng) -> Dataset {
    let scale = 1.0 / (n as f64).sqrt();
    let mut indptr = Vec::with_capacity(m + 1);
    indptr.push(0);
    let mut indices = Vec::with_capacity(m * n);
    let mut values = Vec::with_capacity(m * n);
    for _ in 0..m {
        for c in 0..n {
            indices.push(c as u32);
            values.push(rng.next_gaussian() * scale);
        }
        indptr.push(indices.len());
    }
    let a = Csr::from_parts(m, n, indptr, indices, values);
    let y = planted_labels(&a, DEFAULT_LABEL_NOISE, rng);
    Dataset { name: name.to_string(), a, y }
}

/// Labels from a planted Gaussian model with a given flip fraction.
pub fn planted_labels(a: &Csr, label_noise: f64, rng: &mut Prng) -> Vec<f64> {
    let xstar: Vec<f64> = (0..a.cols()).map(|_| rng.next_gaussian()).collect();
    let mut margins = vec![0.0; a.rows()];
    a.spmv(&xstar, &mut margins);
    margins
        .iter()
        .map(|&mg| {
            let base = if mg >= 0.0 { 1.0 } else { -1.0 };
            if rng.next_f64() < label_noise {
                -base
            } else {
                base
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::NnzStats;

    #[test]
    fn skewed_has_exact_zbar_and_shape() {
        let mut rng = Prng::new(1);
        let d = sparse_skewed("t", 50, 40, 6, 0.8, &mut rng);
        assert_eq!(d.m(), 50);
        assert_eq!(d.n(), 40);
        assert!((d.zbar() - 6.0).abs() < 1e-12);
        for r in 0..50 {
            assert_eq!(d.a.row_nnz(r), 6);
        }
    }

    #[test]
    fn zbar_capped_at_n() {
        let mut rng = Prng::new(2);
        let d = sparse_skewed("t", 5, 3, 10, 0.0, &mut rng);
        assert!((d.zbar() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn skew_exponent_increases_column_imbalance() {
        let mut rng = Prng::new(3);
        let flat = sparse_skewed("f", 400, 200, 8, 0.0, &mut rng);
        let skew = sparse_skewed("s", 400, 200, 8, 1.0, &mut rng);
        let (sf, ss) = (NnzStats::of(&flat.a), NnzStats::of(&skew.a));
        assert!(
            ss.cols.imbalance() > 2.0 * sf.cols.imbalance(),
            "flat κ={} skew κ={}",
            sf.cols.imbalance(),
            ss.cols.imbalance()
        );
        assert!(ss.col_gini > sf.col_gini + 0.2);
    }

    #[test]
    fn dense_is_dense() {
        let mut rng = Prng::new(4);
        let d = dense("e", 10, 7, &mut rng);
        assert_eq!(d.a.nnz(), 70);
        assert!((d.zbar() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn labels_are_pm_one_and_learnable() {
        let mut rng = Prng::new(5);
        let d = sparse_uniform("l", 300, 50, 10, &mut rng);
        assert!(d.y.iter().all(|&y| y == 1.0 || y == -1.0));
        // Not degenerate: both classes present.
        let pos = d.y.iter().filter(|&&y| y > 0.0).count();
        assert!(pos > 30 && pos < 270, "pos={pos}");
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let mut r1 = Prng::new(77);
        let mut r2 = Prng::new(77);
        let d1 = sparse_skewed("a", 20, 30, 5, 0.5, &mut r1);
        let d2 = sparse_skewed("a", 20, 30, 5, 0.5, &mut r2);
        assert_eq!(d1.a, d2.a);
        assert_eq!(d1.y, d2.y);
    }
}
