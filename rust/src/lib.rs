//! # hybrid-sgd
//!
//! A from-scratch reproduction of *"Communication-Efficient, 2D Parallel
//! Stochastic Gradient Descent for Distributed-Memory Optimization"*
//! (Devarakonda & Kannan, 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate implements:
//!
//! * **[`solvers`]** — the full solver family of the paper: sequential SGD,
//!   mini-batch SGD, FedAvg (1D-row + deferred averaging), s-step SGD
//!   (1D-column + recurrence unrolling), 2D SGD, and **HybridSGD** — the 2D
//!   `p = p_r × p_c` mesh generalization in which row teams run s-step
//!   bundles and column teams average every `τ` steps.
//! * **[`sparse`]** — the CSR sparse-BLAS substrate (the role Intel MKL plays
//!   in the paper's C++ implementation).
//! * **[`data`]** — LIBSVM reader/writer plus matched-profile synthetic
//!   generators for the paper's four evaluation datasets.
//! * **[`partition`]** — the three column partitioners of §7.3 (`rows`,
//!   `nnz`-greedy, `cyclic`) and the two-objective partitioner selector.
//! * **[`mesh`]** / **[`comm`]** — the 2D processor mesh and a
//!   message-passing substrate with real-thread and deterministic
//!   simulated-clock executors (the role Cray MPICH plays in the paper).
//! * **[`collectives`]** — the pluggable collective-algorithm layer the
//!   engine charges Allreduces through: recursive doubling, ring, and
//!   Rabenseifner schedules with per-algorithm Hockney accounting, a
//!   Hockney-costed auto-selector (the MPI tuning-table analogue), and
//!   the `Linear` oracle preserving the seed engine's charging. Reduced
//!   values are bit-identical across algorithms (canonical reduction
//!   order); only charged time/message/word books change.
//! * **[`timeline`]** — the event-driven per-rank timeline engine:
//!   nonblocking collectives as schedules of steps, compute/communication
//!   overlap charging (`OverlapPolicy`, the `--overlap` knob), the
//!   reduce-scatter-only charging path, and a critical-path analyzer
//!   reporting which phase each rank's makespan is bound by. Trajectories
//!   never change across overlap policies; hidden transfer seconds are
//!   booked in their own [`metrics::PhaseBook`] column.
//! * **[`obs`]** — the observability layer over the timeline: streaming
//!   trace export ([`obs::TraceSink`]) to JSONL and Chrome/Perfetto
//!   `trace_event` files (one track per rank in `chrome://tracing`), the
//!   versioned end-of-run summary TSV (`obs::summary`), the per-bundle
//!   health/fidelity metrics layer (`obs::metrics` + `obs::health`:
//!   typed metric registry, convergence verdicts, predicted-vs-charged
//!   drift gauges, OpenMetrics export via `train --metrics-out`), and —
//!   with [`timeline::CriticalPath::windowed`] — the sliding-window
//!   critical-path analytics the bound-aware retuner reads. Export is
//!   observation-only: trajectories and charged books are bit-identical
//!   with tracing or metrics on or off.
//! * **[`costmodel`]** — the closed-form α-β-γ model (Eq. 4), the optima
//!   `s*`/`b*` (Eq. 5/6), the topology rule (Eq. 7), the regime taxonomy
//!   (Table 5) and every empirical refinement of §6.5 (cache-aware γ(W),
//!   rank-aware β(q), κ load-imbalance multiplier, sync-skew).
//! * **[`compute`]** / **[`runtime`]** — pluggable compute backends: a pure
//!   Rust `f64` backend and an XLA/PJRT backend that executes the
//!   AOT-compiled JAX+Pallas artifacts (Python never runs at request time).
//! * **[`experiments`]** — one reproduction driver per paper table/figure.
//! * **[`fault`]** — seeded, deterministic fault injection (stragglers,
//!   worker crashes, checkpoint corruption, dropped connections) driving
//!   the serve stack's self-healing recovery paths in chaos tests.

pub mod collectives;
pub mod comm;
pub mod compute;
pub mod costmodel;
pub mod data;
pub mod experiments;
pub mod fault;
pub mod mesh;
pub mod metrics;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod sparse;
pub mod timeline;
pub mod util;

/// Word size in bytes for all dataset / model words (FP64, matching the
/// paper's `w = 8` in every bandwidth expression).
pub const WORD_BYTES: usize = 8;
