//! Acceptance suite for the run-telemetry layer (`obs`):
//!
//! * tracing is **observation-only** — weights, walls, traces, and
//!   charged books are bit-identical with two live sinks attached vs
//!   none, across the overlap × selector × rs_row grid;
//! * the recorded event log **reconciles with the books** — per
//!   `(phase, rank)`, span sums equal the `PhaseBook` charged/wait/
//!   hidden columns to 1e-9 (exactly, in fact) for every simulated
//!   phase, and disjoint bundle windows tile the whole-run
//!   `CriticalPath`;
//! * the exported files agree with the log they were drained from —
//!   JSONL line-for-span with bit-lossless times, Perfetto one `X`
//!   event per span and one named track per rank;
//! * checkpoint schema v2 carries the event log **byte-for-byte**
//!   (checkpoint → resume → checkpoint reproduces the file, and a
//!   resumed run finishes with the full-history timeline);
//! * `RetunePolicy::BoundAware` reads the **sliding window**, not the
//!   whole-run average: injected ancient history flips the whole-run
//!   axis but not the recorded retune (regression for ROADMAP item 5).

use hybrid_sgd::collectives::{BoundBy, SelectorSource};
use hybrid_sgd::comm::OverlapPolicy;
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::HybridConfig;
use hybrid_sgd::data::synth;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::metrics::{Phase, PhaseBook};
use hybrid_sgd::obs::{JsonlSink, PerfettoSink, TraceSink};
use hybrid_sgd::solvers::{RetunePolicy, RunOpts, SessionBuilder, SolverRun};
use hybrid_sgd::sparse::GramStrategy;
use hybrid_sgd::timeline::CriticalPath;
use hybrid_sgd::util::Prng;
use std::cell::RefCell;
use std::io::{self, Write};
use std::rc::Rc;

/// Apply a prebuilt [`RunOpts`] through the per-knob builder surface
/// (the whole-struct `.opts(..)` compat path is retired).
fn with_opts<'a>(b: SessionBuilder<'a>, o: &RunOpts) -> SessionBuilder<'a> {
    b.eta(o.eta)
        .max_bundles(o.max_bundles)
        .eval_every(o.eval_every)
        .target_loss(o.target_loss)
        .backend(o.backend)
        .lanes(o.lanes)
        .charging(o.charging)
        .profile(o.profile.clone())
        .algo(o.algo)
        .selector(o.selector)
        .overlap(o.overlap)
        .rs_row(o.rs_row)
        .gram(o.gram)
        .record_timeline(o.timeline)
        .seed(o.seed)
}

/// A `Write` the test keeps a handle to after the sink is boxed away
/// into the session's observer.
#[derive(Clone, Default)]
struct ShareBuf(Rc<RefCell<Vec<u8>>>);

impl ShareBuf {
    fn take_string(&self) -> String {
        String::from_utf8(self.0.borrow().clone()).expect("sinks emit utf-8")
    }
}

impl Write for ShareBuf {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.borrow_mut().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

fn bits(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

fn books_equal(a: &PhaseBook, b: &PhaseBook) -> bool {
    Phase::all().iter().filter(|ph| ph.in_algorithm_total()).all(|&ph| {
        a.mean_charged(ph).to_bits() == b.mean_charged(ph).to_bits()
            && a.mean_wait(ph).to_bits() == b.mean_wait(ph).to_bits()
            && a.mean_hidden(ph).to_bits() == b.mean_hidden(ph).to_bits()
    }) && a.words == b.words
        && a.messages == b.messages
}

fn runs_equal(a: &SolverRun, b: &SolverRun) -> bool {
    bits(&a.x) == bits(&b.x)
        && a.sim_wall.to_bits() == b.sim_wall.to_bits()
        && a.bundles_run == b.bundles_run
        && a.time_to_target.map(f64::to_bits) == b.time_to_target.map(f64::to_bits)
        && a.trace.len() == b.trace.len()
        && a.trace.iter().zip(&b.trace).all(|(p, q)| p.loss.to_bits() == q.loss.to_bits())
        && books_equal(&a.book, &b.book)
}

/// Everything the cost model simulates lands on the timeline; only the
/// `Metrics` phase (measured host time) is book-only by design.
fn simulated(ph: Phase) -> bool {
    ph != Phase::Metrics
}

/// Tracing on (both exporters live) vs off: bit-identical runs across
/// the overlap × selector × rs_row grid. Sinks only observe.
#[test]
fn prop_tracing_is_observation_only_across_knob_grid() {
    let mut rng = Prng::new(0x0B5E);
    let ds = synth::sparse_skewed("obs-toy", 150, 44, 5, 0.6, &mut rng);
    let be = NativeBackend;
    for overlap in [OverlapPolicy::Off, OverlapPolicy::Bundle] {
        for selector in [SelectorSource::Analytic, SelectorSource::Measured] {
            for rs_row in [false, true] {
                let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 6, 3);
                let opts = RunOpts {
                    max_bundles: 5,
                    eval_every: 2,
                    overlap,
                    rs_row,
                    selector,
                    gram: GramStrategy::Auto,
                    ..Default::default()
                };
                let plain =
                    with_opts(SessionBuilder::new(&be, &ds, cfg), &opts).run_to_end();
                let jsonl = ShareBuf::default();
                let perfetto = ShareBuf::default();
                let traced = with_opts(SessionBuilder::new(&be, &ds, cfg), &opts)
                    .trace_sink(Box::new(JsonlSink::new(jsonl.clone())))
                    .trace_sink(Box::new(PerfettoSink::new(perfetto.clone())))
                    .run_to_end();
                assert!(
                    runs_equal(&plain, &traced),
                    "tracing moved the run (overlap {overlap:?}, {selector:?}, rs_row {rs_row})"
                );
                // And the sinks saw every span exactly once.
                let lines = jsonl.take_string().lines().count();
                assert_eq!(lines, traced.timeline.events().len(), "jsonl line per span");
                let x_events = perfetto.take_string().matches("\"ph\":\"X\"").count();
                assert_eq!(x_events, traced.timeline.events().len(), "perfetto X per span");
            }
        }
    }
}

/// The recorded spans reconcile with the phase books: per (phase, rank)
/// the charged/wait/hidden span sums equal the book columns to 1e-9,
/// under both charging regimes. Windowed analyses tile the whole run.
#[test]
fn span_sums_match_phase_book_and_windows_tile() {
    let mut rng = Prng::new(0x57A75);
    let ds = synth::sparse_skewed("sum-toy", 180, 48, 6, 0.8, &mut rng);
    let be = NativeBackend;
    for overlap in [OverlapPolicy::Off, OverlapPolicy::Bundle] {
        let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 8, 3);
        let run = SessionBuilder::new(&be, &ds, cfg)
            .overlap(overlap)
            .max_bundles(6)
            .eval_every(2)
            .run_to_end();
        let p = run.book.ranks();
        assert!(!run.timeline.events().is_empty(), "recording is on by default");
        let cp = CriticalPath::analyze(&run.timeline);
        for ph in Phase::all().into_iter().filter(|&ph| simulated(ph)) {
            for r in 0..p {
                let (c, w, h) = (cp.charged_of(ph, r), cp.wait_of(ph, r), cp.hidden_of(ph, r));
                assert!(
                    (c - run.book.charged_of(ph, r)).abs() <= 1e-9,
                    "{overlap:?} {ph:?} rank {r}: spans {c} vs book {}",
                    run.book.charged_of(ph, r)
                );
                assert!((w - run.book.wait_of(ph, r)).abs() <= 1e-9);
                assert!((h - run.book.hidden_of(ph, r)).abs() <= 1e-9);
            }
        }
        // All-covering window: event-for-event identical to analyze().
        let hi = run.timeline.events().iter().map(|e| e.bundle).max().unwrap();
        let all = CriticalPath::windowed(&run.timeline, hi + 1);
        for ph in Phase::all() {
            for r in 0..p {
                assert_eq!(all.charged_of(ph, r).to_bits(), cp.charged_of(ph, r).to_bits());
            }
        }
        // Disjoint 2-bundle windows tile the whole run.
        for ph in Phase::all() {
            for r in 0..p {
                let mut charged = 0.0;
                let mut hidden = 0.0;
                let mut lo = 0;
                while lo <= hi {
                    let win = CriticalPath::analyze_range(&run.timeline, lo, lo + 1);
                    charged += win.charged_of(ph, r);
                    hidden += win.hidden_of(ph, r);
                    lo += 2;
                }
                assert!((charged - cp.charged_of(ph, r)).abs() <= 1e-9, "{ph:?} rank {r}");
                assert!((hidden - cp.hidden_of(ph, r)).abs() <= 1e-9);
            }
        }
    }
}

/// The exported JSONL agrees with the log it drained: per (phase, rank)
/// the file's span durations sum to the book's charged seconds (times
/// are shortest-roundtrip, so the parse is bit-lossless), and Perfetto
/// names every rank's track once.
#[test]
fn exported_files_reconcile_with_books() {
    let mut rng = Prng::new(0xF11E5);
    let ds = synth::sparse_skewed("file-toy", 160, 40, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 2), 2, 6, 2);
    let jsonl = ShareBuf::default();
    let perfetto = ShareBuf::default();
    let run = SessionBuilder::new(&be, &ds, cfg)
        .max_bundles(5)
        .trace_sink(Box::new(JsonlSink::new(jsonl.clone())))
        .trace_sink(Box::new(PerfettoSink::new(perfetto.clone())))
        .run_to_end();
    let p = run.book.ranks();

    // Hand-rolled field extraction (the build is offline, no serde).
    fn field<'a>(line: &'a str, key: &str) -> &'a str {
        let at = line.find(key).unwrap_or_else(|| panic!("{key} missing in {line}"));
        let rest = &line[at + key.len()..];
        let end = rest.find([',', '}']).expect("well-formed span object");
        rest[..end].trim_matches('"')
    }
    // charged[phase][rank] summed from the file, analyzer accumulation
    // order (file order == event order).
    let n = Phase::all().len();
    let mut charged = vec![vec![0.0f64; p]; n];
    let text = jsonl.take_string();
    for line in text.lines() {
        let rank: usize = field(line, "\"rank\":").parse().unwrap();
        let phase: Phase = field(line, "\"phase\":").parse().expect("known phase");
        let kind = field(line, "\"kind\":");
        let t0: f64 = field(line, "\"t_start\":").parse().unwrap();
        let t1: f64 = field(line, "\"t_end\":").parse().unwrap();
        assert!(t1 >= t0, "spans run forward");
        let pi = Phase::all().iter().position(|&q| q == phase).unwrap();
        if kind != "hidden" {
            charged[pi][rank] += t1 - t0;
        }
    }
    for (pi, ph) in Phase::all().into_iter().enumerate() {
        if !simulated(ph) {
            continue;
        }
        for r in 0..p {
            assert!(
                (charged[pi][r] - run.book.charged_of(ph, r)).abs() <= 1e-9,
                "{ph:?} rank {r}: file says {}, book says {}",
                charged[pi][r],
                run.book.charged_of(ph, r)
            );
        }
    }
    // Perfetto: wrapper + one named track per rank, span count matches.
    let pj = perfetto.take_string();
    assert!(pj.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
    assert!(pj.trim_end().ends_with("]}"));
    assert_eq!(pj.matches("\"ph\":\"X\"").count(), run.timeline.events().len());
    for r in 0..p {
        assert_eq!(
            pj.matches(&format!("\"args\":{{\"name\":\"rank {r}\"}}")).count(),
            1,
            "rank {r} named exactly once"
        );
    }
}

/// Per-bundle traffic deltas: `BundleReport::words_delta` /
/// `messages_delta` telescope to the final book means.
#[test]
fn bundle_traffic_deltas_telescope_to_book_totals() {
    let mut rng = Prng::new(0xDE17A);
    let ds = synth::sparse_skewed("delta-toy", 150, 40, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 6, 2);
    let mut session = SessionBuilder::new(&be, &ds, cfg).max_bundles(6).build();
    let mut words = 0.0;
    let mut messages = 0.0;
    let mut bundles = 0;
    while let Some(report) = session.step_bundle() {
        words += report.words_delta;
        messages += report.messages_delta;
        bundles += 1;
        assert!(report.words_delta >= 0.0 && report.messages_delta >= 0.0);
    }
    assert_eq!(bundles, 6);
    let run = session.finish();
    assert!((words - run.book.mean_words()).abs() <= 1e-9 * (1.0 + words.abs()));
    assert!((messages - run.book.mean_messages()).abs() <= 1e-9 * (1.0 + messages.abs()));
    assert!(words > 0.0, "a 2x4 hybrid run moves words");
}

/// Checkpoint schema v2 carries the event log byte-for-byte: resuming
/// and immediately re-checkpointing reproduces the file exactly, and a
/// resumed run ends with the full-history timeline (same span count and
/// same analyzer verdicts as the uninterrupted run).
#[test]
fn checkpoint_roundtrips_the_event_log_byte_for_byte() {
    let mut rng = Prng::new(0xC4E7);
    let ds = synth::sparse_skewed("ckpt-obs-toy", 140, 40, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let dir = std::env::temp_dir().join(format!("obs_trace_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for overlap in [OverlapPolicy::Off, OverlapPolicy::Bundle] {
        let cfg = HybridConfig::new(Mesh::new(2, 3), 2, 5, 2);
        let builder = || {
            SessionBuilder::new(&be, &ds, cfg)
                .overlap(overlap)
                .max_bundles(6)
                .eval_every(2)
        };
        let straight = builder().run_to_end();

        let p1 = dir.join(format!("first_{overlap:?}.tsv"));
        let p2 = dir.join(format!("second_{overlap:?}.tsv"));
        let mut first = builder().build();
        for _ in 0..3 {
            let _ = first.step_bundle();
        }
        first.checkpoint(&p1).unwrap();
        assert!(!first.timeline().events().is_empty());
        drop(first);

        let mut resumed = builder().resume(&p1).unwrap();
        resumed.checkpoint(&p2).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        let b2 = std::fs::read(&p2).unwrap();
        assert!(b1 == b2, "{overlap:?}: resume must restore the checkpoint byte-for-byte");
        assert!(
            String::from_utf8_lossy(&b1).lines().any(|l| l.starts_with("event\t")),
            "schema v2 checkpoints carry event rows"
        );

        while !resumed.is_done() {
            let _ = resumed.step_bundle();
        }
        let resumed = resumed.finish();
        assert_eq!(
            resumed.timeline.events().len(),
            straight.timeline.events().len(),
            "{overlap:?}: resumed run keeps the whole history"
        );
        let a = CriticalPath::analyze(&straight.timeline);
        let b = CriticalPath::analyze(&resumed.timeline);
        for ph in Phase::all() {
            for r in 0..straight.book.ranks() {
                assert_eq!(
                    a.charged_of(ph, r).to_bits(),
                    b.charged_of(ph, r).to_bits(),
                    "{overlap:?} {ph:?} rank {r}: restored spans are bit-identical"
                );
            }
        }
    }
    std::fs::remove_dir_all(dir).unwrap();
}

/// The bound-aware regression: doctor a checkpoint with overwhelming
/// ancient history (latency-heavy spans stamped at old bundles, plus
/// dominant compute spans inside the upcoming window), resume, and let
/// the cadence fire. A whole-run reader would report Latency; the
/// sliding window must report the recent (compute-bound ⇒ Balanced)
/// regime — which is exactly what the recorded retune carries.
#[test]
fn bound_aware_retune_reads_the_window_not_the_whole_run() {
    let mut rng = Prng::new(0xB0B);
    let ds = synth::sparse_skewed("window-toy", 160, 48, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 6, 2);
    let dir = std::env::temp_dir().join(format!("obs_trace_retune_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("doctored.tsv");

    let builder = || {
        SessionBuilder::new(&be, &ds, cfg)
            .max_bundles(4)
            .retune(RetunePolicy::BoundAware { every: 2 })
    };
    let mut session = builder().build();
    for _ in 0..2 {
        let _ = session.step_bundle();
    }
    assert_eq!(session.retunes().len(), 1, "first check fires at bundle 2");
    session.checkpoint(&path).unwrap();
    drop(session);

    // Doctor the checkpoint: 1e9 s of sstep-comm wait stamped at bundles
    // 0-1 (ancient history) and 1e7 s of spgemv compute stamped at
    // bundles 2-3 (the window the next check will read).
    let text = std::fs::read_to_string(&path).unwrap();
    let declared: usize = text
        .lines()
        .find_map(|l| l.strip_prefix("meta\tevents\t"))
        .and_then(|rest| rest.split('\t').next())
        .expect("v2 checkpoints declare an event count")
        .parse()
        .unwrap();
    let mut doctored = text.replace(
        &format!("meta\tevents\t{declared}\t-\t-\t-"),
        &format!("meta\tevents\t{}\t-\t-\t-", declared + 4),
    );
    for (j, (cell, end)) in [
        ("sstep_comm/wait/0", "1000000000"),
        ("sstep_comm/wait/1", "1000000000"),
        ("spgemv/compute/2", "10000000"),
        ("spgemv/compute/3", "10000000"),
    ]
    .into_iter()
    .enumerate()
    {
        doctored.push_str(&format!("event\t{}\t0\t{cell}\t0\t{end}\n", declared + j));
    }
    std::fs::write(&path, doctored).unwrap();

    let mut tuned = builder().resume(&path).unwrap();
    while !tuned.is_done() {
        let _ = tuned.step_bundle();
    }
    assert_eq!(tuned.retunes().len(), 2, "second check fires at bundle 4");
    let recorded = tuned.retunes()[1];
    let run = tuned.finish();

    let whole = CriticalPath::analyze(&run.timeline);
    let whole_axis = whole.bound_axis(whole.makespan_rank());
    assert_eq!(whole_axis, BoundBy::Latency, "the injected history dominates a whole-run read");
    let win = CriticalPath::windowed(&run.timeline, 2);
    let win_axis = win.bound_axis(win.makespan_rank());
    assert_eq!(win_axis, BoundBy::Balanced, "the window is compute-bound by construction");
    assert_eq!(
        recorded.axis, win_axis,
        "the retuner must report the windowed axis, not the whole-run one"
    );
    assert_ne!(recorded.axis, whole_axis, "regression: retuner no longer reads the whole run");

    std::fs::remove_dir_all(dir).unwrap();
}

/// A sink that fails mid-run only disables export — the run itself is
/// unaffected and bit-identical to the untraced one.
#[test]
fn failing_sink_never_fails_the_run() {
    struct ExplodingSink {
        left: usize,
    }
    impl TraceSink for ExplodingSink {
        fn span(&mut self, _: &hybrid_sgd::timeline::Event) -> io::Result<()> {
            if self.left == 0 {
                return Err(io::Error::other("disk full"));
            }
            self.left -= 1;
            Ok(())
        }
    }
    let mut rng = Prng::new(0xFA11);
    let ds = synth::sparse_skewed("fail-toy", 120, 36, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 2), 2, 5, 2);
    let plain = SessionBuilder::new(&be, &ds, cfg).max_bundles(4).run_to_end();
    let traced = SessionBuilder::new(&be, &ds, cfg)
        .max_bundles(4)
        .trace_sink(Box::new(ExplodingSink { left: 3 }))
        .run_to_end();
    assert!(runs_equal(&plain, &traced), "a dying sink must not move the run");
}
