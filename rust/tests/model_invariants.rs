//! Cost-model invariants, property-tested across random configurations.

use hybrid_sgd::costmodel::model::{self, DataShape};
use hybrid_sgd::costmodel::{optima, topology, CalibProfile, HybridConfig};
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::util::proptest::{check, Config};

fn random_shape(rng: &mut hybrid_sgd::util::Prng) -> DataShape {
    DataShape {
        m: 10_000 + rng.next_below(5_000_000),
        n: 1_000 + rng.next_below(10_000_000),
        zbar: 5.0 + rng.next_below(2000) as f64,
    }
}

fn random_cfg(rng: &mut hybrid_sgd::util::Prng) -> HybridConfig {
    let p_r = 1 << rng.next_below(8);
    let p_c = 1 << rng.next_below(8);
    let s = 1 + rng.next_below(16);
    let b = 1 + rng.next_below(128);
    let tau = s + rng.next_below(50);
    HybridConfig::new(Mesh::new(p_r, p_c), s, b, tau)
}

/// Every Eq. 4 term is nonnegative and finite, and the total is the sum.
#[test]
fn prop_breakdown_well_formed() {
    let profile = CalibProfile::perlmutter();
    check(
        Config { cases: 200, seed: 0x11 },
        "eq4 well-formed",
        |rng| (random_cfg(rng), random_shape(rng)),
        |(cfg, data)| {
            let bd = model::eval(cfg, data, &profile);
            let terms = [bd.compute, bd.latency, bd.gram_bw, bd.sync_bw];
            terms.iter().all(|t| t.is_finite() && *t >= 0.0)
                && (bd.total() - terms.iter().sum::<f64>()).abs() < 1e-12 * bd.total().max(1.0)
        },
    );
}

/// Doubling τ never increases the flat-model total at the corners where τ
/// only appears in denominators (sync + latency amortization).
#[test]
fn prop_tau_monotone() {
    check(
        Config { cases: 100, seed: 0x22 },
        "tau amortizes comm",
        |rng| (random_cfg(rng), random_shape(rng)),
        |(cfg, data)| {
            let t1 = model::eval_flat(cfg, data, 1e-6, 1e-9, 1e-10);
            let mut cfg2 = *cfg;
            cfg2.tau *= 2;
            let t2 = model::eval_flat(&cfg2, data, 1e-6, 1e-9, 1e-10);
            t2.latency <= t1.latency + 1e-15 && t2.sync_bw <= t1.sync_bw + 1e-15
        },
    );
}

/// The closed-form s* (Eq. 5) tracks the integer sweep argmin of the full
/// Eq. 4 within one grid neighbour, across random shapes.
#[test]
fn prop_s_star_matches_sweep() {
    check(
        Config { cases: 60, seed: 0x33 },
        "s* vs sweep",
        |rng| {
            let mut cfg = random_cfg(rng);
            cfg.b = 8 + rng.next_below(64);
            // Eq. 5 presumes an *interior* mesh (both teams exist): at a
            // 1D corner one of the communication terms vanishes from
            // Eq. 4 but not from the closed form, so the comparison is
            // out of scope there.
            cfg.mesh =
                hybrid_sgd::mesh::Mesh::new(cfg.mesh.p_r.max(2), cfg.mesh.p_c.max(2));
            (cfg, random_shape(rng))
        },
        |(cfg, data)| {
            let (alpha, beta, gamma) = (3.6e-6, 2.7e-9, 1e-10);
            let s_cont = optima::s_star(cfg, data, alpha, beta, gamma).clamp(1.0, 64.0);
            let s_sweep = optima::sweep_s(cfg, data, alpha, beta, gamma, 64) as f64;
            // Within a factor-2 bracket of the discrete argmin (the
            // continuous optimum of a convex A·s + B/s is within that of
            // any integer neighbour).
            s_cont <= 2.0 * s_sweep + 1.0 && s_sweep <= 2.0 * s_cont + 1.0
        },
    );
}

/// The topology rule always yields a valid factorization with p_c ≤ p and
/// p_r·p_c = p, and the cache term only ever *raises* p_c.
#[test]
fn prop_topology_rule_valid() {
    check(
        Config { cases: 200, seed: 0x44 },
        "rule validity",
        |rng| {
            let p = 1 + rng.next_below(4096);
            let n = 1 + rng.next_below(100_000_000);
            (n, p)
        },
        |&(n, p)| {
            let m = topology::mesh_rule(n, p, 64, 1 << 20);
            let base = topology::mesh_rule(1, p, 64, 1 << 20); // cache never binds at n=1
            m.p() == p && m.p_c >= base.p_c.min(p)
        },
    );
}

/// Eq. 4's mesh corners reproduce the Table 2/3 baseline structure:
/// FedAvg corner has no Gram term, s-step corner has no sync term, and
/// the interior has both.
#[test]
fn prop_corner_structure() {
    let profile = CalibProfile::perlmutter();
    check(
        Config { cases: 100, seed: 0x55 },
        "corner structure",
        |rng| {
            let p = 2 << rng.next_below(9);
            (p, random_shape(rng))
        },
        |&(p, data)| {
            let fed = model::eval(&HybridConfig::fedavg_corner(p, 32, 10), &data, &profile);
            let sstep = model::eval(&HybridConfig::sstep_corner(p, 4, 32), &data, &profile);
            fed.gram_bw == 0.0 && sstep.sync_bw == 0.0 && fed.sync_bw > 0.0 && sstep.gram_bw > 0.0
        },
    );
}

/// Rank-aware β refinement: crossing the node boundary (p_c > R) never
/// makes the Gram term cheaper at fixed payload.
#[test]
fn prop_node_boundary_step() {
    let profile = CalibProfile::perlmutter();
    check(
        Config { cases: 50, seed: 0x66 },
        "beta step at R",
        |rng| random_shape(rng),
        |data| {
            let intra =
                model::eval(&HybridConfig::new(Mesh::new(4, 64), 4, 32, 10), data, &profile);
            let inter =
                model::eval(&HybridConfig::new(Mesh::new(4, 128), 4, 32, 10), data, &profile);
            inter.gram_bw >= intra.gram_bw
        },
    );
}
