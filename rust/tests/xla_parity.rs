//! Cross-layer parity: the XLA/PJRT backend (AOT JAX + Pallas artifacts)
//! must agree with the native Rust backend — and both must satisfy the
//! shared conformance suite. Requires `make artifacts` *and* a build with
//! the `xla` feature (skips cleanly with a message otherwise).

use hybrid_sgd::compute::{conformance_suite, ComputeBackend, NativeBackend};
use hybrid_sgd::runtime::{artifacts_dir, XlaBackend};
use hybrid_sgd::util::Prng;

fn load_or_skip() -> Option<XlaBackend> {
    if cfg!(not(feature = "xla")) {
        eprintln!("skipping: built without the `xla` feature (stub backend cannot load)");
        return None;
    }
    let dir = artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(XlaBackend::load(dir).expect("load artifacts"))
}

#[test]
fn xla_backend_passes_conformance() {
    let Some(be) = load_or_skip() else { return };
    conformance_suite(&be);
    assert!(be.served.load(std::sync::atomic::Ordering::Relaxed) > 0, "nothing ran on XLA");
}

#[test]
fn sstep_parity_native_vs_xla_across_grid() {
    let Some(xla) = load_or_skip() else { return };
    let native = NativeBackend;
    let mut rng = Prng::new(0xBEEF);
    for &s in &[1usize, 2, 4, 8] {
        for &b in &[8usize, 16, 32] {
            let q = s * b;
            // PSD-ish lower-triangular Gram from a random Y.
            let n = 24;
            let y: Vec<f64> = (0..q * n).map(|_| rng.next_gaussian()).collect();
            let mut g = vec![0.0; q * q];
            for i in 0..q {
                for l in 0..=i {
                    g[i * q + l] = (0..n).map(|c| y[i * n + c] * y[l * n + c]).sum();
                }
            }
            let v: Vec<f64> = (0..q).map(|_| rng.next_gaussian()).collect();
            let eta_over_b = 0.01 / b as f64;
            let mut z_native = vec![0.0; q];
            native.sstep_correct(s, b, &g, &v, eta_over_b, &mut z_native);
            let mut z_xla = vec![0.0; q];
            xla.sstep_correct(s, b, &g, &v, eta_over_b, &mut z_xla);
            for i in 0..q {
                assert!(
                    (z_native[i] - z_xla[i]).abs() < 1e-12,
                    "s={s} b={b} i={i}: native {} vs xla {}",
                    z_native[i],
                    z_xla[i]
                );
            }
        }
    }
    assert_eq!(xla.fallbacks.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn dense_grad_parity() {
    let Some(xla) = load_or_skip() else { return };
    let native = NativeBackend;
    let mut rng = Prng::new(0xD15C);
    for &(b, n) in &[(16usize, 256usize), (32, 512)] {
        let a: Vec<f64> = (0..b * n).map(|_| rng.next_gaussian()).collect();
        let x0: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut xn = x0.clone();
        native.dense_grad_step(b, n, &a, &mut xn, 0.1);
        let mut xx = x0.clone();
        xla.dense_grad_step(b, n, &a, &mut xx, 0.1);
        for c in 0..n {
            assert!((xn[c] - xx[c]).abs() < 1e-11, "b={b} n={n} c={c}");
        }
    }
}

#[test]
fn loss_parity_with_chunk_padding() {
    let Some(xla) = load_or_skip() else { return };
    let native = NativeBackend;
    let mut rng = Prng::new(0x105);
    // Deliberately not a multiple of any chunk size, and bigger than one chunk.
    let margins: Vec<f64> = (0..20_001).map(|_| rng.next_gaussian() * 30.0).collect();
    let ln = native.loss_sum(&margins);
    let lx = xla.loss_sum(&margins);
    assert!(
        (ln - lx).abs() < 1e-7 * ln.abs().max(1.0),
        "native {ln} vs xla {lx}"
    );
}

#[test]
fn sigmoid_parity_with_padding() {
    let Some(xla) = load_or_skip() else { return };
    let native = NativeBackend;
    let mut rng = Prng::new(0x51);
    for m in [1usize, 100, 128, 200, 512] {
        let v: Vec<f64> = (0..m).map(|_| rng.next_gaussian() * 5.0).collect();
        let mut on = vec![0.0; m];
        native.sigmoid_residual(&v, &mut on);
        let mut ox = vec![0.0; m];
        xla.sigmoid_residual(&v, &mut ox);
        for i in 0..m {
            assert!((on[i] - ox[i]).abs() < 1e-14, "m={m} i={i}");
        }
    }
}

/// End-to-end: the HybridSGD solver produces the same trajectory on both
/// backends (the correction recurrence is the only backend-served op on
/// the solver path).
#[test]
fn solver_trajectory_parity() {
    let Some(xla) = load_or_skip() else { return };
    use hybrid_sgd::costmodel::HybridConfig;
    use hybrid_sgd::data::synth;
    use hybrid_sgd::mesh::Mesh;
    use hybrid_sgd::partition::Partitioner;
    use hybrid_sgd::solvers::{HybridSolver, RunOpts};

    let mut rng = Prng::new(77);
    let ds = synth::sparse_skewed("parity", 128, 64, 6, 0.7, &mut rng);
    let cfg = HybridConfig::new(Mesh::new(2, 2), 2, 8, 4);
    let opts = RunOpts { max_bundles: 6, eval_every: 0, ..Default::default() };

    let run_native = HybridSolver::new(&NativeBackend).run(&ds, cfg, Partitioner::Cyclic, &opts);
    let run_xla = HybridSolver::new(&xla).run(&ds, cfg, Partitioner::Cyclic, &opts);
    assert_eq!(run_native.x.len(), run_xla.x.len());
    for (a, b) in run_native.x.iter().zip(&run_xla.x) {
        assert!((a - b).abs() < 1e-10, "{a} vs {b}");
    }
}
