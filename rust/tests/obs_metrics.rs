//! Acceptance suite for the health/metrics layer (`obs::metrics` +
//! `obs::health`):
//!
//! * metrics export is **observation-only** — weights, walls, traces,
//!   and charged books are bit-identical with sinks attached vs none,
//!   across the overlap × selector × rs_row grid, and the bundle-wall
//!   histogram's bucket counts sum to its observation count;
//! * the fidelity monitor is **calibrated against the engine** — on an
//!   exactly-uniform dataset (every row holds the same nnz in every
//!   column residue class) a `Modeled` run's predicted books match the
//!   charged books and every drift gauge reads < 1e-9, while a doctored
//!   `predict_profile` provably drifts and flags;
//! * `RetunePolicy::DriftGated` fires only while the model is lying,
//!   and never moves the trajectory;
//! * `loss_delta` follows the eval cadence (`None` off-eval and on the
//!   first eval, never stale), and the health verdict trips to
//!   `Diverged` on a poisoned run;
//! * the `PrometheusSink` scrape file is valid OpenMetrics carrying the
//!   loss, one-hot health, per-phase drift, and overlap-efficiency
//!   series, and the TSV sink leads with its schema row.

use hybrid_sgd::collectives::SelectorSource;
use hybrid_sgd::comm::{ExecBackend, OverlapPolicy};
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::{CalibProfile, HybridConfig};
use hybrid_sgd::data::{synth, Dataset};
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::metrics::{Phase, PhaseBook};
use hybrid_sgd::obs::{
    DriftKey, HealthStatus, MetricRegistry, MetricsSink, MetricsTsvSink, PrometheusSink,
};
use hybrid_sgd::partition::Partitioner;
use hybrid_sgd::solvers::{RetunePolicy, RunOpts, SessionBuilder, SolverRun};
use hybrid_sgd::sparse::{Csr, GramStrategy};
use hybrid_sgd::util::Prng;
use std::cell::RefCell;
use std::io;
use std::rc::Rc;

/// Apply a prebuilt [`RunOpts`] through the per-knob builder surface
/// (the whole-struct `.opts(..)` compat path is retired).
fn with_opts<'a>(b: SessionBuilder<'a>, o: &RunOpts) -> SessionBuilder<'a> {
    b.eta(o.eta)
        .max_bundles(o.max_bundles)
        .eval_every(o.eval_every)
        .target_loss(o.target_loss)
        .backend(o.backend)
        .lanes(o.lanes)
        .charging(o.charging)
        .profile(o.profile.clone())
        .algo(o.algo)
        .selector(o.selector)
        .overlap(o.overlap)
        .rs_row(o.rs_row)
        .gram(o.gram)
        .record_timeline(o.timeline)
        .seed(o.seed)
}

fn bits(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

fn books_equal(a: &PhaseBook, b: &PhaseBook) -> bool {
    Phase::all().iter().filter(|ph| ph.in_algorithm_total()).all(|&ph| {
        a.mean_charged(ph).to_bits() == b.mean_charged(ph).to_bits()
            && a.mean_wait(ph).to_bits() == b.mean_wait(ph).to_bits()
            && a.mean_hidden(ph).to_bits() == b.mean_hidden(ph).to_bits()
    }) && a.words == b.words
        && a.messages == b.messages
}

fn runs_equal(a: &SolverRun, b: &SolverRun) -> bool {
    bits(&a.x) == bits(&b.x)
        && a.sim_wall.to_bits() == b.sim_wall.to_bits()
        && a.bundles_run == b.bundles_run
        && a.trace.len() == b.trace.len()
        && a.trace.iter().zip(&b.trace).all(|(p, q)| p.loss.to_bits() == q.loss.to_bits())
        && books_equal(&a.book, &b.book)
}

/// A sink the test keeps a handle to after it is boxed away into the
/// session: records the sample count and the final registry snapshot.
#[derive(Clone, Default)]
struct CaptureSink {
    state: Rc<RefCell<Captured>>,
}

#[derive(Default)]
struct Captured {
    samples: usize,
    /// Last OpenMetrics exposition.
    text: String,
    /// Last `hybridsgd_bundle_wall_seconds` snapshot.
    wall_hist: Option<(u64, f64, Vec<u64>)>,
    /// Last `hybridsgd_bundles` counter value.
    bundles_total: f64,
}

impl MetricsSink for CaptureSink {
    fn sample(&mut self, _bundle: usize, reg: &MetricRegistry) -> io::Result<()> {
        let mut st = self.state.borrow_mut();
        st.samples += 1;
        let mut buf = Vec::new();
        reg.write_openmetrics(&mut buf)?;
        st.text = String::from_utf8(buf).expect("exposition is utf-8");
        st.wall_hist = reg.hist_of("hybridsgd_bundle_wall_seconds", &[]);
        st.bundles_total = reg.value_of("hybridsgd_bundles", &[]).unwrap_or(f64::NAN);
        Ok(())
    }
}

/// Metrics on (a live capturing sink) vs off: bit-identical runs across
/// the overlap × selector × rs_row grid, one sample per bundle, and the
/// wall histogram's buckets always sum to its count.
#[test]
fn prop_metrics_are_observation_only_across_knob_grid() {
    let mut rng = Prng::new(0x3E7A1);
    let ds = synth::sparse_skewed("metrics-toy", 150, 44, 5, 0.6, &mut rng);
    let be = NativeBackend;
    for overlap in [OverlapPolicy::Off, OverlapPolicy::Bundle] {
        for selector in [SelectorSource::Analytic, SelectorSource::Measured] {
            for rs_row in [false, true] {
                let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 6, 3);
                let opts = RunOpts {
                    max_bundles: 5,
                    eval_every: 2,
                    overlap,
                    rs_row,
                    selector,
                    gram: GramStrategy::Auto,
                    ..Default::default()
                };
                let plain =
                    with_opts(SessionBuilder::new(&be, &ds, cfg), &opts).run_to_end();
                let cap = CaptureSink::default();
                let metered = with_opts(SessionBuilder::new(&be, &ds, cfg), &opts)
                    .metrics_sink(Box::new(cap.clone()))
                    .run_to_end();
                assert!(
                    runs_equal(&plain, &metered),
                    "metrics moved the run (overlap {overlap:?}, {selector:?}, rs_row {rs_row})"
                );
                let st = cap.state.borrow();
                assert_eq!(st.samples, 5, "one sample per bundle");
                assert_eq!(st.bundles_total, 5.0, "bundle counter counts bundles");
                let (count, _sum, buckets) =
                    st.wall_hist.clone().expect("wall histogram exists");
                assert_eq!(count, 5, "one wall observation per bundle");
                assert_eq!(buckets.iter().sum::<u64>(), count, "buckets sum to count");
                assert_eq!(st.text.lines().last(), Some("# EOF"), "valid exposition");
            }
        }
    }
}

/// Every row gets exactly `z` nonzeros in each column residue class mod
/// `p_c`, so under the `Cyclic` partitioner each rank block's batch nnz
/// equals the uniform-density expectation `q·z̄·n_local/n` *exactly* —
/// the fixture on which the analytic prediction is bit-honest.
fn exact_uniform_dataset(m: usize, n: usize, p_c: usize, z: usize) -> Dataset {
    assert!(n % p_c == 0 && z <= n / p_c);
    let per_class = n / p_c;
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values = Vec::new();
    for i in 0..m {
        let mut cols: Vec<usize> = Vec::new();
        for c in 0..p_c {
            for k in 0..z {
                cols.push(c + p_c * ((i + k) % per_class));
            }
        }
        cols.sort_unstable();
        for col in cols {
            indices.push(col as u32);
            values.push(1.0 + 0.125 * ((i + col) % 7) as f64);
        }
        indptr.push(indices.len());
    }
    let y = (0..m).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    Dataset {
        name: "exact-uniform".into(),
        a: Csr::from_parts(m, n, indptr, indices, values),
        y,
    }
}

/// On the calibration-consistent fixture every drift gauge — the four
/// compute phases, both comm phases, words, messages — reads ~0 (< 1e-9)
/// under both overlap policies and both row-reduce charging paths.
#[test]
fn drift_is_zero_on_calibration_consistent_run() {
    let ds = exact_uniform_dataset(48, 8, 2, 2);
    let be = NativeBackend;
    for overlap in [OverlapPolicy::Off, OverlapPolicy::Bundle] {
        for rs_row in [false, true] {
            let cfg = HybridConfig::new(Mesh::new(2, 2), 2, 4, 2);
            // Pinned to the simulator: under `Threads` the wall-fidelity
            // gauges ride along and the drift snapshot grows past 8.
            let run = SessionBuilder::new(&be, &ds, cfg)
                .partitioner(Partitioner::Cyclic)
                .backend(ExecBackend::Sim)
                .overlap(overlap)
                .rs_row(rs_row)
                .max_bundles(6)
                .eval_every(2)
                .run_to_end();
            assert_eq!(run.drift.len(), 8, "6 algorithm phases + words + messages");
            for d in &run.drift {
                assert!(
                    d.ewma.abs() < 1e-9 && d.last.abs() < 1e-9 && !d.flagged,
                    "{} drifted on a self-consistent run \
                     (overlap {overlap:?}, rs_row {rs_row}): ewma {} last {}",
                    d.key.name(),
                    d.ewma,
                    d.last
                );
            }
        }
    }
}

/// A prediction profile every one of whose rates is 50× the charging
/// profile's: times mispredict by 49/50 everywhere, while the schedule
/// choices (and so words/messages) are unchanged — uniform scaling
/// preserves every selector argmin.
fn doctored_profile() -> CalibProfile {
    let mut p = CalibProfile::perlmutter();
    p.gamma_flop *= 50.0;
    p.gamma_flop_dense *= 50.0;
    for pt in p.intra.iter_mut().chain(p.inter.iter_mut()) {
        pt.alpha *= 50.0;
        pt.beta *= 50.0;
    }
    for t in p.tiers.iter_mut() {
        t.gamma *= 50.0;
    }
    p
}

/// The doctored profile drifts every seconds gauge past the threshold
/// (relative error 49/50) while the traffic gauges stay exact.
#[test]
fn doctored_predict_profile_flags_every_phase() {
    let ds = exact_uniform_dataset(48, 8, 2, 2);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 2), 2, 4, 2);
    let run = SessionBuilder::new(&be, &ds, cfg)
        .partitioner(Partitioner::Cyclic)
        .backend(ExecBackend::Sim)
        .predict_profile(doctored_profile())
        .max_bundles(6)
        .eval_every(2)
        .run_to_end();
    for d in &run.drift {
        match d.key {
            DriftKey::Phase(_) => assert!(
                d.flagged && d.ewma > 0.9,
                "{} must drift under a 50x prediction profile (ewma {})",
                d.key.name(),
                d.ewma
            ),
            DriftKey::Words | DriftKey::Messages => assert!(
                !d.flagged && d.ewma.abs() < 1e-9,
                "traffic books are rate-independent ({}: ewma {})",
                d.key.name(),
                d.ewma
            ),
            DriftKey::Wall(_) => unreachable!(
                "wall-fidelity gauges only appear under the threads backend"
            ),
        }
    }
}

/// Drift-gated retuning fires only while the row-reduce drift gauge is
/// flagged: never on a self-consistent run, on cadence under a doctored
/// prediction profile — and either way the trajectory is untouched.
#[test]
fn drift_gated_retune_fires_only_when_the_model_lies() {
    let mut rng = Prng::new(0xD61F7);
    let ds = synth::sparse_skewed("gate-toy", 150, 44, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 6, 3);
    let builder = || SessionBuilder::new(&be, &ds, cfg).max_bundles(6).eval_every(2);

    let plain = builder().run_to_end();
    let clean = builder().retune(RetunePolicy::DriftGated { every: 2 }).run_to_end();
    assert!(
        clean.retunes.is_empty(),
        "the row-reduce prediction is exact by construction, so a \
         self-consistent run must never trip the gate"
    );
    assert!(runs_equal(&plain, &clean), "an idle gate must not move the run");

    let gated = builder()
        .retune(RetunePolicy::DriftGated { every: 2 })
        .predict_profile(doctored_profile())
        .run_to_end();
    assert!(!gated.retunes.is_empty(), "a lying model must trip the gate");
    assert_eq!(gated.retunes[0].bundle, 2, "first firing on the cadence");
    // A retune may re-pin the row collective (charged seconds move), but
    // values are bit-identical across collective algorithms.
    assert_eq!(bits(&plain.x), bits(&gated.x), "retuning must not move the weights");
    assert_eq!(plain.trace.len(), gated.trace.len());
    for (p, q) in plain.trace.iter().zip(&gated.trace) {
        assert_eq!(p.loss.to_bits(), q.loss.to_bits(), "losses are trajectory state");
    }
}

/// `loss_delta` follows the eval cadence: `None` on bundles without an
/// eval and on the first eval, the exact previous-eval difference after
/// that; health moves Initializing → Healthy with the first eval.
#[test]
fn loss_delta_and_health_follow_the_eval_cadence() {
    let mut rng = Prng::new(0xCADE);
    let ds = synth::sparse_skewed("cadence-toy", 150, 44, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 6, 3);
    let mut session =
        SessionBuilder::new(&be, &ds, cfg).max_bundles(5).eval_every(2).eta(0.05).build();

    let r1 = session.step_bundle().unwrap();
    assert!(r1.eval.is_none() && r1.loss_delta.is_none());
    assert_eq!(r1.health, HealthStatus::Initializing, "no eval yet");
    let r2 = session.step_bundle().unwrap();
    assert!(r2.eval.is_some());
    assert!(r2.loss_delta.is_none(), "first eval has no previous point");
    assert_eq!(r2.health, HealthStatus::Healthy);
    let r3 = session.step_bundle().unwrap();
    assert!(r3.eval.is_none() && r3.loss_delta.is_none(), "off-cadence bundle stays None");
    let r4 = session.step_bundle().unwrap();
    let d = r4.loss_delta.expect("second eval has a delta");
    let (l2, l4) = (r2.eval.unwrap().loss, r4.eval.unwrap().loss);
    assert_eq!(d.to_bits(), (l4 - l2).to_bits(), "delta is the previous-eval difference");
    let r5 = session.step_bundle().unwrap();
    assert!(r5.eval.is_some(), "the final budgeted bundle always evals");
    assert!(r5.loss_delta.is_some());
    let run = session.finish();
    assert_eq!(run.health, HealthStatus::Healthy);
}

/// A poisoned run (astronomical step size overflows the update norm)
/// trips the tripwire on the very first bundle and the verdict is
/// sticky through the run summary.
#[test]
fn poisoned_run_reports_diverged() {
    let mut rng = Prng::new(0xBAD);
    let ds = synth::sparse_skewed("poison-toy", 120, 36, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 2), 2, 5, 2);
    let mut session =
        SessionBuilder::new(&be, &ds, cfg).max_bundles(3).eval_every(1).eta(1e300).build();
    let r1 = session.step_bundle().unwrap();
    assert!(!r1.update_norm.is_finite(), "1e300 steps overflow the update norm");
    assert_eq!(r1.health, HealthStatus::Diverged);
    while !session.is_done() {
        let _ = session.step_bundle();
    }
    let run = session.finish();
    assert_eq!(run.health, HealthStatus::Diverged, "divergence is sticky");
}

/// The scrape file is valid OpenMetrics carrying every required series,
/// the health gauge is one-hot, and the TSV series file leads with its
/// versioned schema row.
#[test]
fn prometheus_scrape_file_is_valid_and_complete() {
    let mut rng = Prng::new(0x9120);
    let ds = synth::sparse_skewed("scrape-toy", 150, 44, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let dir = std::env::temp_dir().join(format!("obs_metrics_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let prom = dir.join("run.prom");
    let tsv = dir.join("run.tsv");

    let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 6, 3);
    let run = SessionBuilder::new(&be, &ds, cfg)
        .max_bundles(4)
        .eval_every(2)
        .metrics_sink(Box::new(PrometheusSink::create(&prom).unwrap()))
        .metrics_sink(Box::new(MetricsTsvSink::create(&tsv)))
        .run_to_end();
    assert_eq!(run.health, HealthStatus::Healthy);

    let text = std::fs::read_to_string(&prom).unwrap();
    assert_eq!(text.lines().last(), Some("# EOF"), "exposition ends with EOF");
    for needle in [
        "# TYPE hybridsgd_bundles counter",
        "hybridsgd_bundles_total 4",
        "# TYPE hybridsgd_loss gauge",
        "hybridsgd_loss ",
        "hybridsgd_phase_seconds_total{phase=\"sstep_comm\",kind=\"charged\"}",
        "hybridsgd_model_drift{series=\"sstep_comm\"}",
        "hybridsgd_model_drift{series=\"words\"}",
        "hybridsgd_health{state=\"healthy\"} 1",
        "hybridsgd_overlap_efficiency{window=\"bundle\"}",
        "hybridsgd_bundle_wall_seconds_bucket{le=\"+Inf\"} 4",
        "hybridsgd_bundle_wall_seconds_count 4",
        "hybridsgd_rank_busy_seconds{rank=\"7\"}",
    ] {
        assert!(text.contains(needle), "scrape file is missing `{needle}`:\n{text}");
    }
    // One-hot: exactly one health state reads 1.
    let ones = HealthStatus::all()
        .iter()
        .filter(|s| text.contains(&format!("hybridsgd_health{{state=\"{}\"}} 1", s.name())))
        .count();
    assert_eq!(ones, 1, "health gauge is one-hot");

    let series = std::fs::read_to_string(&tsv).unwrap();
    let mut lines = series.lines();
    assert_eq!(lines.next(), Some("kind\tbundle\tmetric\tlabels\tvalue"), "tsv header");
    let meta = lines.next().unwrap();
    assert!(
        meta.starts_with("meta\t-\tschema\t-\t"),
        "schema row leads the series: {meta}"
    );
    assert!(series.lines().any(|l| l.starts_with("sample\t4\thybridsgd_loss\t")));

    std::fs::remove_dir_all(dir).unwrap();
}
