//! Acceptance harness for the `pallas-serve` daemon:
//!
//! * **admission concurrency** — four planner-admitted jobs run at once
//!   on one daemon (the submit replies themselves say `running`, since
//!   admission happens under the submit lock before the reply);
//! * **packing** — a daemon with a 2-rank budget queues the second job
//!   with a queue position, and canceling a queued job frees it;
//! * **watch streams** — per-bundle telemetry replays from the start or
//!   from a `--from` cursor, losses land on the eval cadence, and the
//!   stream terminates with a `done` frame;
//! * **prompt cancel** — a running job stops at the next bundle
//!   boundary when canceled;
//! * **kill-and-restart equivalence** — a daemon killed mid-flight
//!   (no spool writes, simulating SIGKILL) restarts, resumes every
//!   in-flight job from its periodic checkpoint, and finishes
//!   **bit-identical** to an uninterrupted reference run — trajectory
//!   *and* charged books — including a job running under
//!   `--overlap bundle` with a posted row reduce in flight;
//! * **graceful drain** — `shutdown` checkpoints running jobs, marks
//!   them `interrupted`, and a restart resumes them bit-identically;
//! * **protocol robustness** — malformed, truncated, and newer-schema
//!   frames produce typed `err` frames, never a panic or a wedged
//!   daemon;
//! * **service metrics** — the daemon's scrape file carries the job
//!   lifecycle counters and per-job gauges.

use hybrid_sgd::collectives::{Algorithm, SelectorSource};
use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::serve::{
    plan_job, Client, ClientError, Daemon, DaemonConfig, ErrCode, JobRecord, JobSpec, JobState,
    Plan, Spool,
};
use hybrid_sgd::sparse::GramStrategy;
use hybrid_sgd::timeline::OverlapPolicy;
use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Fresh per-test spool directory (removed up front so reruns start
/// clean; tests use distinct tags so `cargo test` can parallelize).
fn spool_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("serve_harness_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// A small rcv1-profile job: 2 requested ranks shape to a 1x2 mesh
/// under the topology rule, so the footprint is 2 slots.
fn quick_spec(bundles: usize, ckpt_every: usize) -> JobSpec {
    JobSpec {
        dataset: DatasetSpec::Rcv1Like,
        scale: 0.05,
        p: 2,
        bundles,
        eval_every: 3,
        eta: 0.1,
        tau: 10,
        seed: 0x5EED,
        target: None,
        ckpt_every,
        deadline: None,
    }
}

/// Poll a condition until it holds or a generous deadline passes.
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(120);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Checkpoint lines for the bit-identity compare. The only
/// host-nondeterministic rows are the `book metrics` entries (measured
/// eval wall, charged as host time) and therefore the `checksum` trailer
/// hashing them; everything else — weights, cursors, clocks, traffic,
/// phase books, trace, pending collectives, the event log — must match
/// byte for byte.
fn ckpt_lines(path: &Path) -> Vec<String> {
    fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
        .lines()
        .filter(|l| !l.starts_with("book\tmetrics\t") && !l.starts_with("checksum\t"))
        .map(|l| l.to_string())
        .collect()
}

// ---------------------------------------------------------------------
// Admission concurrency + watch + cancel + wire shutdown
// ---------------------------------------------------------------------

#[test]
fn four_planner_admitted_jobs_run_concurrently() {
    let daemon = Daemon::start(DaemonConfig::local(spool_dir("concurrent"))).unwrap();
    let client = Client::new(daemon.addr().to_string());

    // Long budget, no checkpoints: these jobs exist to occupy slots.
    let mut spec = quick_spec(100_000, 0);
    spec.eval_every = 1000;
    let mut ids = Vec::new();
    for seed in 0..4 {
        spec.seed = seed;
        let (row, plan) = client.submit(&spec).unwrap();
        // The planner shaped the mesh and the scheduler admitted the job
        // before replying: with 4 × 2 = 8 ranks against 16 slots, every
        // submit reply must already say `running`.
        assert_eq!(row.state, JobState::Running, "job {} not admitted", row.id);
        assert_eq!(plan.ranks(), 2, "1x2 mesh expected for p=2");
        assert!(plan.s >= 1 && plan.b >= 1);
        assert!(plan.per_epoch_s.is_finite() && plan.per_epoch_s > 0.0);
        ids.push(row.id);
    }
    let running = client
        .status(None)
        .unwrap()
        .iter()
        .filter(|r| r.state == JobState::Running)
        .count();
    assert!(running >= 4, "expected >= 4 concurrent sessions, saw {running}");

    // Prompt cancel: each worker notices at the next bundle boundary.
    for &id in &ids {
        let ack = client.cancel(id).unwrap();
        assert!(ack.contains("cancel"), "unexpected ack {ack:?}");
    }
    for &id in &ids {
        let done = client.watch(id, 0, |_| {}).unwrap();
        assert_eq!(done.state, JobState::Canceled);
        assert!(done.bundles < 100_000, "canceled job ran to budget");
    }

    // Wire shutdown: the daemon drains and refuses new work.
    assert_eq!(client.shutdown().unwrap(), "draining");
    let err = client.submit(&spec).unwrap_err();
    assert_eq!(err.code(), Some(ErrCode::ShuttingDown));
    daemon.wait();
}

#[test]
fn packing_queues_jobs_beyond_the_rank_budget() {
    let mut cfg = DaemonConfig::local(spool_dir("packing"));
    cfg.slots = 2; // exactly one 1x2 job fits
    let daemon = Daemon::start(cfg).unwrap();
    let client = Client::new(daemon.addr().to_string());

    let (a, _) = client.submit(&quick_spec(100_000, 0)).unwrap();
    assert_eq!(a.state, JobState::Running);
    let (b, _) = client.submit(&quick_spec(100_000, 0)).unwrap();
    assert_eq!(b.state, JobState::Queued);
    assert_eq!(b.queue_pos, Some(0), "queued job must report its position");

    // Canceling a queued job never involves a worker.
    assert_eq!(client.cancel(b.id).unwrap(), "canceled");
    let row = &client.status(Some(b.id)).unwrap()[0];
    assert_eq!(row.state, JobState::Canceled);
    // Cancel is idempotent on terminal jobs.
    assert_eq!(client.cancel(b.id).unwrap(), "already canceled");

    client.cancel(a.id).unwrap();
    client.watch(a.id, 0, |_| {}).unwrap();
    daemon.shutdown();
    daemon.wait();
}

// ---------------------------------------------------------------------
// Watch streams
// ---------------------------------------------------------------------

#[test]
fn watch_replays_telemetry_and_honours_the_cursor() {
    let daemon = Daemon::start(DaemonConfig::local(spool_dir("watch"))).unwrap();
    let client = Client::new(daemon.addr().to_string());

    let (row, _) = client.submit(&quick_spec(12, 0)).unwrap();
    let mut frames = Vec::new();
    let done = client.watch(row.id, 0, |f| frames.push(f.clone())).unwrap();

    assert_eq!(done.state, JobState::Done);
    assert_eq!(done.bundles, 12);
    assert!(done.loss.is_some(), "final bundle always evals");
    assert_eq!(frames.len(), 12, "one telem frame per bundle");
    for (i, f) in frames.iter().enumerate() {
        assert_eq!(f.bundle, i + 1, "frames stream in bundle order");
        assert_eq!(f.id, row.id);
        // eval cadence: every 3rd bundle plus the budget boundary.
        assert_eq!(f.loss.is_some(), (i + 1) % 3 == 0 || i + 1 == 12);
        assert!(f.words >= 0.0);
    }

    // A second watch with a cursor replays only the tail.
    let mut tail = Vec::new();
    let done2 = client.watch(row.id, 6, |f| tail.push(f.bundle)).unwrap();
    assert_eq!(done2.state, JobState::Done);
    assert_eq!(tail, vec![7, 8, 9, 10, 11, 12]);

    let err = client.watch(999, 0, |_| {}).unwrap_err();
    assert_eq!(err.code(), Some(ErrCode::UnknownJob));

    daemon.shutdown();
    daemon.wait();
}

// ---------------------------------------------------------------------
// Admission validation
// ---------------------------------------------------------------------

#[test]
fn planner_rejects_bad_specs_with_typed_errors() {
    let cfg = DaemonConfig::local(spool_dir("plan"));

    let reject = |mutate: fn(&mut JobSpec), needle: &str| {
        let mut spec = quick_spec(10, 0);
        mutate(&mut spec);
        let e = plan_job(&spec, &cfg).unwrap_err();
        assert_eq!(e.code, ErrCode::BadValue, "{e}");
        assert!(e.msg.contains(needle), "{e}");
    };
    reject(|s| s.scale = 0.0, "scale");
    reject(|s| s.scale = 1.5, "scale");
    reject(|s| s.p = 0, "p must");
    reject(|s| s.bundles = 0, "bundles");
    reject(|s| s.eval_every = 0, "eval_every");
    reject(|s| s.eta = -0.1, "eta");
    reject(|s| s.eta = f64::NAN, "eta");
    reject(|s| s.tau = 0, "tau");
    reject(|s| s.target = Some(f64::INFINITY), "target");
    // A job whose mesh footprint exceeds the rank budget is refused at
    // admission, not queued forever.
    reject(|s| s.p = 64, "slots");

    // The same rejection crosses the wire as a typed err frame.
    let daemon = Daemon::start(cfg).unwrap();
    let client = Client::new(daemon.addr().to_string());
    let mut spec = quick_spec(10, 0);
    spec.scale = 0.0;
    let err = client.submit(&spec).unwrap_err();
    assert_eq!(err.code(), Some(ErrCode::BadValue));
    let err = client.cancel(42).unwrap_err();
    assert_eq!(err.code(), Some(ErrCode::UnknownJob));
    daemon.shutdown();
    daemon.wait();
}

// ---------------------------------------------------------------------
// Kill-and-restart / drain-and-restart equivalence
// ---------------------------------------------------------------------

/// A hand-crafted record pinning `--overlap bundle` (the planner may or
/// may not pick it; the equivalence claim must cover a checkpoint taken
/// with a posted row reduce in flight, so the harness pins it). The
/// daemon re-queues whatever the spool holds and runs the record's
/// exact knobs.
fn bundle_overlap_record(seed: u64, bundles: usize) -> JobRecord {
    JobRecord {
        id: 1,
        spec: JobSpec {
            dataset: DatasetSpec::Rcv1Like,
            scale: 0.05,
            p: 2,
            bundles,
            eval_every: 5,
            eta: 0.1,
            tau: 10,
            seed,
            target: None,
            ckpt_every: 2,
            deadline: None,
        },
        plan: Plan {
            mesh: Mesh::new(1, 2),
            s: 3,
            b: 4,
            algo: Algorithm::RecursiveDoubling,
            overlap: OverlapPolicy::Bundle,
            gram: GramStrategy::Scatter,
            source: SelectorSource::Analytic,
            per_epoch_s: 1.0,
        },
        state: JobState::Queued,
        bundles_done: 0,
        last_loss: None,
        retries: 0,
        note: None,
    }
}

/// Run `rec` to completion on a fresh daemon and return the final
/// checkpoint lines — the uninterrupted reference trajectory.
fn reference_run(tag: &str, rec: &JobRecord) -> Vec<String> {
    let dir = spool_dir(tag);
    let spool = Spool::open(&dir).unwrap();
    spool.save(rec).unwrap();
    let daemon = Daemon::start(DaemonConfig::local(&dir)).unwrap();
    let client = Client::new(daemon.addr().to_string());
    let done = client.watch(rec.id, 0, |_| {}).unwrap();
    assert_eq!(done.state, JobState::Done);
    assert_eq!(done.bundles, rec.spec.bundles);
    daemon.shutdown();
    daemon.wait();
    ckpt_lines(&spool.ckpt_path(rec.id))
}

#[test]
fn kill_and_restart_resumes_bit_identically_under_bundle_overlap() {
    const BUNDLES: usize = 600;
    let rec = bundle_overlap_record(11, BUNDLES);
    let reference = reference_run("kill_ref", &rec);

    // Interrupted run: seed the same record, let it get partway, kill.
    let dir = spool_dir("kill_run");
    let spool = Spool::open(&dir).unwrap();
    spool.save(&rec).unwrap();
    let daemon = Daemon::start(DaemonConfig::local(&dir)).unwrap();
    let client = Client::new(daemon.addr().to_string());
    wait_until("job past bundle 25", || {
        client.status(Some(1)).map(|rows| rows[0].bundles >= 25).unwrap_or(false)
    });
    // Crash: workers abandon their sessions with NO spool writes — the
    // spool holds the admission record and the periodic checkpoints,
    // exactly what a SIGKILL would leave.
    daemon.kill();
    let after = spool.load(spool.record_path(1)).unwrap();
    assert_eq!(after.state, JobState::Running, "a crash must not update the record");
    assert!(after.bundles_done < BUNDLES, "job finished before the kill; raise BUNDLES");
    assert!(spool.ckpt_path(1).exists(), "periodic checkpoint missing");

    // Restart on the same spool: the record re-queues and the worker
    // resumes from the checkpoint — with `overlap bundle`, that
    // checkpoint can carry a posted row reduce still in flight.
    let daemon = Daemon::start(DaemonConfig::local(&dir)).unwrap();
    let client = Client::new(daemon.addr().to_string());
    let done = client.watch(1, 0, |_| {}).unwrap();
    assert_eq!(done.state, JobState::Done);
    assert_eq!(done.bundles, BUNDLES);
    daemon.shutdown();
    daemon.wait();

    let resumed = ckpt_lines(&spool.ckpt_path(1));
    assert!(!resumed.is_empty());
    assert_eq!(
        resumed, reference,
        "kill-and-restart trajectory/books diverged from the uninterrupted run"
    );
}

#[test]
fn graceful_drain_resumes_bit_identically() {
    const BUNDLES: usize = 600;
    let rec = bundle_overlap_record(23, BUNDLES);
    let reference = reference_run("drain_ref", &rec);

    let dir = spool_dir("drain_run");
    let spool = Spool::open(&dir).unwrap();
    spool.save(&rec).unwrap();
    let daemon = Daemon::start(DaemonConfig::local(&dir)).unwrap();
    let client = Client::new(daemon.addr().to_string());
    wait_until("job past bundle 10", || {
        client.status(Some(1)).map(|rows| rows[0].bundles >= 10).unwrap_or(false)
    });
    // Graceful drain: the worker checkpoints at the next bundle
    // boundary (any bundle, not just the ckpt_every cadence) and the
    // record is marked interrupted.
    daemon.shutdown();
    daemon.wait();
    let after = spool.load(spool.record_path(1)).unwrap();
    assert_eq!(after.state, JobState::Interrupted);

    let daemon = Daemon::start(DaemonConfig::local(&dir)).unwrap();
    let client = Client::new(daemon.addr().to_string());
    let done = client.watch(1, 0, |_| {}).unwrap();
    assert_eq!(done.state, JobState::Done);
    assert_eq!(done.bundles, BUNDLES);
    daemon.shutdown();
    daemon.wait();

    let resumed = ckpt_lines(&spool.ckpt_path(1));
    assert_eq!(
        resumed, reference,
        "drain-and-restart trajectory/books diverged from the uninterrupted run"
    );
    // The durable record agrees with the reference outcome too.
    let final_rec = spool.load(spool.record_path(1)).unwrap();
    assert_eq!(final_rec.state, JobState::Done);
    assert_eq!(final_rec.bundles_done, BUNDLES);
    assert!(final_rec.last_loss.is_some());
}

// ---------------------------------------------------------------------
// Protocol robustness
// ---------------------------------------------------------------------

/// One raw request/response round trip, bypassing the typed client.
fn raw_roundtrip(addr: &str, line: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.flush().unwrap();
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply).unwrap();
    reply
}

#[test]
fn malformed_frames_get_typed_errors_and_never_wedge_the_daemon() {
    let daemon = Daemon::start(DaemonConfig::local(spool_dir("robust"))).unwrap();
    let addr = daemon.addr().to_string();
    let client = Client::new(addr.clone());

    let corpus: &[(&str, &str)] = &[
        ("\n", "bad-frame"),                       // empty frame
        ("garbage\n", "bad-frame"),                // wrong magic
        ("ps2\n", "bad-frame"),                    // missing op
        ("ps9\tstatus\tall\n", "bad-version"),     // newer protocol
        ("ps1\tstatus\tall\n", "bad-version"),     // stale client
        ("ps2\tfrobnicate\tx\n", "unknown-op"),    // unknown op
        ("ps2\tstatus\n", "bad-frame"),            // wrong arity
        ("ps2\tstatus\tall\textra\n", "bad-frame"),
        ("ps2\twatch\tnot-a-number\t0\n", "bad-value"),
        ("ps2\tcancel\t999\n", "unknown-job"),
        // submit with an unparseable scale cell
        (
            "ps2\tsubmit\trcv1\tbogus\t2\t10\t3\t0.1\t10\t1\t-\t0\t-\n",
            "bad-value",
        ),
        // submit with an unknown dataset
        (
            "ps2\tsubmit\tnosuch\t0.05\t2\t10\t3\t0.1\t10\t1\t-\t0\t-\n",
            "bad-value",
        ),
    ];
    for (frame, code) in corpus {
        let reply = raw_roundtrip(&addr, frame);
        assert!(
            reply.starts_with("ps2\terr\t"),
            "frame {frame:?} should yield an err frame, got {reply:?}"
        );
        assert!(
            reply.contains(&format!("\t{code}\t")) || reply.contains(&format!("err\t{code}")),
            "frame {frame:?} should report {code}, got {reply:?}"
        );
    }

    // A connection that opens and closes without a newline must not
    // wedge anything.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"ps2\tstat").unwrap();
        drop(s);
    }
    {
        let s = TcpStream::connect(&addr).unwrap();
        drop(s);
    }

    // After the whole corpus, the daemon still serves typed requests.
    assert!(client.status(None).unwrap().is_empty());
    daemon.shutdown();
    daemon.wait();
}

// ---------------------------------------------------------------------
// Service metrics
// ---------------------------------------------------------------------

#[test]
fn scrape_file_carries_service_and_per_job_metrics() {
    let dir = spool_dir("metrics");
    let mut cfg = DaemonConfig::local(&dir);
    let scrape = dir.join("serve.prom");
    cfg.metrics_out = Some(scrape.clone());
    let daemon = Daemon::start(cfg).unwrap();
    let client = Client::new(daemon.addr().to_string());

    let (row, _) = client.submit(&quick_spec(6, 0)).unwrap();
    let done = client.watch(row.id, 0, |_| {}).unwrap();
    assert_eq!(done.state, JobState::Done);
    daemon.shutdown();
    daemon.wait();

    let text = fs::read_to_string(&scrape).unwrap();
    for needle in [
        "hybridsgd_serve_jobs_submitted_total 1",
        "hybridsgd_serve_jobs_done_total 1",
        "hybridsgd_serve_jobs_canceled_total 0",
        "hybridsgd_serve_jobs_failed_total 0",
        "hybridsgd_serve_jobs_running 0",
        "hybridsgd_serve_job_bundles{job=\"1\"} 6",
        // Fault-free run: the recovery families exist, eagerly zeroed.
        "hybridsgd_serve_job_retries_total 0",
        "hybridsgd_serve_ckpt_fallbacks_total 0",
        "hybridsgd_serve_jobs_deadline_exceeded_total 0",
        "hybridsgd_serve_drain_forced_total 0",
        "hybridsgd_serve_jobs_retrying 0",
        "hybridsgd_serve_faults_injected_total{kind=\"crash\"} 0",
        "hybridsgd_serve_faults_injected_total{kind=\"corrupt-ckpt\"} 0",
    ] {
        assert!(text.contains(needle), "scrape missing {needle:?}:\n{text}");
    }
    assert!(
        text.contains("hybridsgd_serve_job_loss{job=\"1\"}"),
        "per-job loss gauge missing:\n{text}"
    );
}

// ---------------------------------------------------------------------
// Client-side protocol errors
// ---------------------------------------------------------------------

#[test]
fn client_reports_transport_and_daemon_errors_distinctly() {
    // Nothing is listening here: pure transport error. Retries are
    // disabled so the refusal surfaces immediately instead of walking
    // the backoff ladder first.
    let client = Client::new("127.0.0.1:1").retries(0);
    match client.status(None) {
        Err(ClientError::Io(_)) => {}
        other => panic!("expected an I/O error, got {other:?}"),
    }
}
