//! The collectives layer's determinism and accounting contracts, verified
//! end to end through the engine and the solver:
//!
//! * every algorithm's reduced values are **bit-identical** to the
//!   `Linear` oracle across mesh shapes, scopes, and ops (property test);
//! * charged time / message / word books genuinely differ by algorithm;
//! * the auto selector's books cross over from recursive doubling to
//!   ring/Rabenseifner as the payload grows;
//! * solver trajectories are invariant under the algorithm policy while
//!   simulated wall time is not.

use hybrid_sgd::collectives::{charge, reduce_scatter_charge, AlgoPolicy, Algorithm};
use hybrid_sgd::comm::{Charging, Engine, OverlapPolicy, Reduce, Scope};
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::{CalibProfile, HybridConfig};
use hybrid_sgd::data::synth;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::metrics::Phase;
use hybrid_sgd::partition::Partitioner;
use hybrid_sgd::solvers::{HybridSolver, RunOpts};
use hybrid_sgd::util::proptest::{check, Config};
use hybrid_sgd::util::Prng;

struct St {
    buf: Vec<f64>,
}

/// Run one allreduce over a fresh engine and return (buffers, sim_wall,
/// messages[0], words[0]).
fn run_allreduce(
    policy: AlgoPolicy,
    mesh: Mesh,
    scope: Scope,
    op: Reduce,
    words: usize,
    data_seed: u64,
) -> (Vec<Vec<u64>>, f64, f64, f64) {
    let mut e =
        Engine::new(mesh, CalibProfile::perlmutter(), Charging::Modeled).with_algo(policy);
    let mut rng = Prng::new(data_seed);
    let mut states: Vec<St> = (0..mesh.p())
        .map(|_| St { buf: (0..words).map(|_| rng.range_f64(-1e6, 1e6)).collect() })
        .collect();
    e.allreduce(Phase::SstepComm, scope, op, &mut states, |s| &mut s.buf);
    let bits: Vec<Vec<u64>> =
        states.iter().map(|s| s.buf.iter().map(|v| v.to_bits()).collect()).collect();
    (bits, e.sim_wall(), e.book.messages[0], e.book.words[0])
}

#[test]
fn prop_all_algorithms_bit_identical_to_linear_oracle() {
    check(
        Config { cases: 48, seed: 0xC011EC7 },
        "algorithm choice never changes reduced values",
        |rng| {
            (
                1 + rng.next_below(5),          // p_r
                1 + rng.next_below(5),          // p_c
                1 + rng.next_below(64),         // words
                rng.next_below(3),              // scope index
                rng.next_below(2),              // op index
                rng.next_u64(),                 // data seed
            )
        },
        |&(p_r, p_c, words, scope_i, op_i, data_seed)| {
            let mesh = Mesh::new(p_r, p_c);
            let scope = [Scope::World, Scope::RowTeam, Scope::ColTeam][scope_i];
            let op = [Reduce::Sum, Reduce::Mean][op_i];
            let (oracle, _, _, _) = run_allreduce(
                AlgoPolicy::Fixed(Algorithm::Linear),
                mesh,
                scope,
                op,
                words,
                data_seed,
            );
            Algorithm::physical().into_iter().all(|algo| {
                let (got, _, _, _) =
                    run_allreduce(AlgoPolicy::Fixed(algo), mesh, scope, op, words, data_seed);
                got == oracle
            }) && {
                let (auto, _, _, _) =
                    run_allreduce(AlgoPolicy::Auto, mesh, scope, op, words, data_seed);
                auto == oracle
            }
        },
    );
}

#[test]
fn charged_books_differ_by_algorithm() {
    // One 4096-word allreduce over 8 ranks: all four pinned policies agree
    // on values (above) but disagree pairwise on charged time, and the
    // physical schedules disagree with the oracle on words.
    let mesh = Mesh::new(1, 8);
    let runs: Vec<(Algorithm, f64, f64, f64)> = Algorithm::all()
        .into_iter()
        .map(|a| {
            let (_, wall, msgs, words) = run_allreduce(
                AlgoPolicy::Fixed(a),
                mesh,
                Scope::World,
                Reduce::Sum,
                4096,
                7,
            );
            (a, wall, msgs, words)
        })
        .collect();
    for i in 0..runs.len() {
        for j in i + 1..runs.len() {
            assert!(
                (runs[i].1 - runs[j].1).abs() > 1e-15,
                "{} and {} charged identical time",
                runs[i].0.name(),
                runs[j].0.name()
            );
        }
    }
    // Linear books the bound's W; ring moves 2W(q−1)/q; recursive doubling
    // log₂q · W.
    let by = |a: Algorithm| runs.iter().find(|r| r.0 == a).unwrap();
    assert_eq!(by(Algorithm::Linear).3, 4096.0);
    assert_eq!(by(Algorithm::RecursiveDoubling).3, 3.0 * 4096.0);
    assert!((by(Algorithm::RingAllreduce).3 - 2.0 * 7.0 / 8.0 * 4096.0).abs() < 1e-9);
}

#[test]
fn auto_books_cross_over_with_payload() {
    // q = 64 world team. Tiny payload: recursive doubling's 6 messages.
    // Huge payload: the ring's 2(q−1) messages. The books prove the
    // selector switched.
    let mesh = Mesh::new(1, 64);
    let (_, _, msgs_small, words_small) =
        run_allreduce(AlgoPolicy::Auto, mesh, Scope::World, Reduce::Sum, 8, 11);
    assert_eq!(msgs_small, 6.0, "tiny payload must book ⌈log₂64⌉ messages");
    assert_eq!(words_small, 6.0 * 8.0);
    let big = 1 << 20;
    let (_, _, msgs_big, words_big) =
        run_allreduce(AlgoPolicy::Auto, mesh, Scope::World, Reduce::Sum, big, 11);
    assert_eq!(msgs_big, 126.0, "huge payload must book the ring's 2(q−1) messages");
    assert!((words_big - 2.0 * 63.0 / 64.0 * big as f64).abs() < 1e-6);
    // And the books match the selector's own account.
    let prof = CalibProfile::perlmutter();
    let (algo_small, cost_small) = charge(&prof, AlgoPolicy::Auto, 64, 8);
    let (algo_big, cost_big) = charge(&prof, AlgoPolicy::Auto, 64, big);
    assert_eq!(algo_small, Algorithm::RecursiveDoubling);
    assert_eq!(algo_big, Algorithm::RingAllreduce);
    assert_eq!(cost_small.messages, msgs_small);
    assert_eq!(cost_big.messages, msgs_big);
}

/// Satellite property: across mesh shapes, s-step depths, and collective
/// policies, `OverlapPolicy::Bundle` never increases `sim_wall` and never
/// changes the solver trajectory (final weights bitwise, final loss
/// equal). The combined `rs_row + Bundle` charging path obeys the same
/// contract.
#[test]
fn prop_bundle_overlap_never_slower_and_trajectory_invariant() {
    let mut rng = Prng::new(0x0E71A9);
    let ds = synth::sparse_skewed("overlap-toy", 180, 64, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let policies = [
        AlgoPolicy::Auto,
        AlgoPolicy::Fixed(Algorithm::Linear),
        AlgoPolicy::Fixed(Algorithm::RecursiveDoubling),
        AlgoPolicy::Fixed(Algorithm::RingAllreduce),
        AlgoPolicy::Fixed(Algorithm::Rabenseifner),
    ];
    check(
        Config { cases: 16, seed: 0xB41D1E },
        "bundle overlap: wall never grows, trajectory never changes",
        |rng| {
            (
                1 + rng.next_below(3),  // p_r
                1 + rng.next_below(3),  // p_c
                1 + rng.next_below(3),  // s
                2 + rng.next_below(7),  // b
                rng.next_below(3),      // tau - s offset
                rng.next_below(5),      // policy index
                rng.next_below(2) == 1, // rs_row
            )
        },
        |&(p_r, p_c, s, b, tau_off, policy_i, rs_row)| {
            let cfg = HybridConfig::new(Mesh::new(p_r, p_c), s, b, s + tau_off);
            let run_with = |overlap: OverlapPolicy| {
                let opts = RunOpts {
                    max_bundles: 6,
                    eval_every: 0,
                    algo: policies[policy_i],
                    overlap,
                    rs_row,
                    ..Default::default()
                };
                HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &opts)
            };
            let off = run_with(OverlapPolicy::Off);
            let bun = run_with(OverlapPolicy::Bundle);
            off.x == bun.x
                && off.final_loss() == bun.final_loss()
                && bun.sim_wall <= off.sim_wall * (1.0 + 1e-12) + 1e-18
                && off.book.mean_hidden(Phase::SstepComm) == 0.0
        },
    );
}

/// Satellite property: the engine's reduce-scatter charging path books no
/// more time/words/messages than the full Allreduce under every policy,
/// while delivering bitwise-identical reduced values, and its books match
/// [`reduce_scatter_charge`]'s account.
#[test]
fn prop_reduce_scatter_books_bounded_by_allreduce_books() {
    let policies = [
        AlgoPolicy::Auto,
        AlgoPolicy::Fixed(Algorithm::Linear),
        AlgoPolicy::Fixed(Algorithm::RecursiveDoubling),
        AlgoPolicy::Fixed(Algorithm::RingAllreduce),
        AlgoPolicy::Fixed(Algorithm::Rabenseifner),
    ];
    check(
        Config { cases: 40, seed: 0x5CA77E2 },
        "reduce-scatter books never exceed allreduce books",
        |rng| {
            (
                2 + rng.next_below(8),    // q
                1 + rng.next_below(2048), // words
                rng.next_below(5),        // policy index
                rng.next_u64(),           // data seed
            )
        },
        |&(q, words, policy_i, data_seed)| {
            let policy = policies[policy_i];
            let mesh = Mesh::new(1, q);
            let run = |rs: bool| {
                let mut e = Engine::new(mesh, CalibProfile::perlmutter(), Charging::Modeled)
                    .with_algo(policy);
                let mut rng = Prng::new(data_seed);
                let mut states: Vec<St> = (0..q)
                    .map(|_| St { buf: (0..words).map(|_| rng.range_f64(-1e6, 1e6)).collect() })
                    .collect();
                if rs {
                    e.reduce_scatter(Phase::SstepComm, Scope::World, Reduce::Sum, &mut states, |s| {
                        &mut s.buf
                    });
                } else {
                    e.allreduce(Phase::SstepComm, Scope::World, Reduce::Sum, &mut states, |s| {
                        &mut s.buf
                    });
                }
                let bits: Vec<Vec<u64>> = states
                    .iter()
                    .map(|s| s.buf.iter().map(|v| v.to_bits()).collect())
                    .collect();
                (bits, e.sim_wall(), e.book.messages[0], e.book.words[0])
            };
            let (v_ar, t_ar, m_ar, w_ar) = run(false);
            let (v_rs, t_rs, m_rs, w_rs) = run(true);
            let (_, rs_cost) = reduce_scatter_charge(&CalibProfile::perlmutter(), policy, q, words);
            v_ar == v_rs
                && t_rs <= t_ar * (1.0 + 1e-12)
                && m_rs <= m_ar + 1e-9
                && w_rs <= w_ar + 1e-9
                && (t_rs - rs_cost.time).abs() <= 1e-15 * (1.0 + rs_cost.time)
                && m_rs == rs_cost.messages
                && w_rs == rs_cost.words
        },
    );
}

/// Satellite property: a measured profile whose per-algorithm points are
/// *generated from* the Hockney model makes `SelectorSource::Measured`
/// reproduce `Analytic`'s `selection_map` exactly — every algorithm
/// sequence and every word-resolution crossover threshold — including
/// after a round trip through the TSV schema (the `calibrate
/// --collectives --save` → `train --profile` path). Every schedule's
/// analytic time is affine in the payload at fixed team size, so the
/// two-point fit loses nothing the selector can see.
#[test]
fn prop_hockney_generated_measured_profile_reproduces_analytic_selection() {
    use hybrid_sgd::collectives::{AutoSelector, SelectorSource};
    use hybrid_sgd::costmodel::calib::AlgoCurves;
    let base = CalibProfile::perlmutter();
    let team_sizes = [2usize, 3, 4, 8, 9, 16, 32, 64, 100, 256, 1024];
    let curves = AlgoCurves::from_hockney(&base, &team_sizes, 1 << 16);
    let dir = std::env::temp_dir().join(format!("collectives_equiv_{}", std::process::id()));
    let path = dir.join("hockney_curves.tsv");
    base.clone().with_algo_curves(curves).to_tsv(&path).unwrap();
    let measured_prof = CalibProfile::from_tsv(&path).unwrap();
    assert!(measured_prof.algo_curves.is_some(), "curves survive the TSV round trip");
    check(
        Config { cases: 32, seed: 0x5E1EC7 },
        "hockney-fitted measured curves reproduce the analytic selection map",
        |rng| (rng.next_below(11), 1 + rng.next_below(1 << 22)),
        |&(qi, max_words)| {
            let q = team_sizes[qi];
            let analytic = AutoSelector::new(&base).selection_map(q, max_words);
            let measured = AutoSelector::new(&measured_prof)
                .with_source(SelectorSource::Measured)
                .selection_map(q, max_words);
            analytic == measured
        },
    );
    std::fs::remove_dir_all(dir).unwrap();
}

/// `--selector measured` end-to-end through the solver: trajectories are
/// bit-identical to `--selector analytic` under *any* curve set (the
/// source steers charged books only), and under Hockney-fitted curves
/// even the charged wall coincides.
#[test]
fn solver_trajectory_invariant_under_selector_source() {
    use hybrid_sgd::collectives::SelectorSource;
    use hybrid_sgd::costmodel::calib::{AlgoCurves, CommPoint};
    let mut rng = Prng::new(0x5E1EC2);
    let ds = synth::sparse_skewed("selector-toy", 200, 80, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 8, 2);
    let run_with = |profile: CalibProfile, selector: SelectorSource| {
        let opts =
            RunOpts { max_bundles: 8, eval_every: 0, profile, selector, ..Default::default() };
        HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &opts)
    };
    let base = CalibProfile::perlmutter();
    let qs = [2usize, 4];
    let hockney = base.clone().with_algo_curves(AlgoCurves::from_hockney(&base, &qs, 1 << 14));
    let a = run_with(base.clone(), SelectorSource::Analytic);
    let m = run_with(hockney, SelectorSource::Measured);
    assert_eq!(a.x, m.x, "selector source changed the trajectory");
    assert_eq!(a.sim_wall, m.sim_wall, "hockney-fitted curves must charge identically");
    // A deliberately warped curve set (ring free, everything else
    // absurd): selection moves, books may move, values must not.
    let mut warped = AlgoCurves::new();
    for algo in Algorithm::physical() {
        for &q in &qs {
            let alpha = if algo == Algorithm::RingAllreduce { 0.0 } else { 1.0 };
            warped.push(algo, CommPoint { ranks: q, alpha, beta: 1e-12 });
        }
    }
    let w = run_with(base.clone().with_algo_curves(warped), SelectorSource::Measured);
    assert_eq!(a.x, w.x, "warped measured curves changed the trajectory");
    assert!(w.sim_wall > 0.0);
}

#[test]
fn solver_trajectory_invariant_under_algorithm_policy() {
    let mut rng = Prng::new(0x50C1A1);
    let ds = synth::sparse_skewed("collectives-toy", 240, 96, 6, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 8, 2);
    let run_with = |policy: AlgoPolicy| {
        let opts = RunOpts { max_bundles: 12, eval_every: 0, algo: policy, ..Default::default() };
        HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &opts)
    };
    let oracle = run_with(AlgoPolicy::Fixed(Algorithm::Linear));
    let mut walls = vec![oracle.sim_wall];
    for algo in Algorithm::physical() {
        let run = run_with(AlgoPolicy::Fixed(algo));
        assert_eq!(run.x, oracle.x, "{} changed the trajectory", algo.name());
        walls.push(run.sim_wall);
    }
    let auto = run_with(AlgoPolicy::Auto);
    assert_eq!(auto.x, oracle.x, "auto changed the trajectory");
    // Charged walls genuinely differ across pinned algorithms.
    for i in 0..walls.len() {
        for j in i + 1..walls.len() {
            assert!((walls[i] - walls[j]).abs() > 1e-15, "walls {i}/{j} coincide");
        }
    }
    // Auto is never slower than the best pinned physical schedule.
    let best_physical =
        walls[1..].iter().copied().fold(f64::INFINITY, f64::min);
    assert!(auto.sim_wall <= best_physical * (1.0 + 1e-9));
}
