//! Data-pipeline integration: registry profiles, LIBSVM round trips, and
//! partition invariants across the whole suite.

use hybrid_sgd::data::{libsvm, DatasetSpec};
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::partition::{stats, ColPartition, MeshPartition, Partitioner};
use hybrid_sgd::sparse::NnzStats;

/// Every registry profile generates, matches its declared shape, and
/// carries learnable labels.
#[test]
fn registry_profiles_generate_and_learn() {
    for spec in DatasetSpec::all() {
        let p = spec.profile();
        let ds = p.generate_scaled(0.04, 1);
        assert!(ds.m() >= 64 && ds.n() >= 32, "{}", p.name);
        let l0 = ds.loss(&vec![0.0; ds.n()]);
        assert!((l0 - (2.0f64).ln()).abs() < 1e-9, "{}: zero-model loss {l0}", p.name);
        // A few full-gradient steps must reduce the loss — labels are
        // planted, not random.
        let x = hybrid_sgd::solvers::reference::gradient_descent(
            &ds,
            &hybrid_sgd::compute::NativeBackend,
            5.0,
            120,
        );
        assert!(ds.loss(&x) < 0.90 * l0, "{} did not learn", p.name);
    }
}

/// Skew ordering across the suite matches Table 6's qualitative ranking:
/// url-like is the most column-skewed, epsilon/synthetic are balanced.
#[test]
fn skew_ordering_matches_paper_suite() {
    let gini = |spec: DatasetSpec| {
        let ds = spec.profile().generate_scaled(0.04, 2);
        NnzStats::of(&ds.a).col_gini
    };
    let url = gini(DatasetSpec::UrlLike);
    let news = gini(DatasetSpec::News20Like);
    let rcv1 = gini(DatasetSpec::Rcv1Like);
    let synth = gini(DatasetSpec::SyntheticUniform);
    assert!(url > rcv1, "url {url} vs rcv1 {rcv1}");
    assert!(news > rcv1, "news {news} vs rcv1 {rcv1}");
    assert!(rcv1 > synth, "rcv1 {rcv1} vs synthetic {synth}");
}

/// LIBSVM round trip at dataset scale: write → read preserves everything.
#[test]
fn libsvm_roundtrip_full_dataset() {
    let ds = DatasetSpec::Rcv1Like.profile().generate_scaled(0.03, 3);
    let text = libsvm::to_string(&ds);
    let back = libsvm::parse(&text, "rt", Some(ds.n())).unwrap();
    assert_eq!(back.m(), ds.m());
    assert_eq!(back.y, ds.y);
    assert_eq!(back.a.nnz(), ds.a.nnz());
    assert_eq!(back.a.indices(), ds.a.indices());
    for (a, b) in back.a.values().iter().zip(ds.a.values()) {
        assert_eq!(a, b, "lossless float round trip");
    }
}

/// Partition invariants hold on every (profile, partitioner, p_c) cell:
/// exact column cover, κ ≥ 1, per-part ownership bijective, and the 2D
/// assembly conserves nonzeros.
#[test]
fn partition_invariants_across_suite() {
    for spec in [DatasetSpec::UrlLike, DatasetSpec::News20Like, DatasetSpec::Rcv1Like] {
        let ds = spec.profile().generate_scaled(0.03, 4);
        for p_c in [4usize, 16] {
            for policy in Partitioner::all() {
                let part = ColPartition::build(&ds.a, p_c, policy);
                assert_eq!(part.n_local.iter().sum::<usize>(), ds.n());
                assert!(part.kappa() >= 1.0 - 1e-12);
                assert_eq!(
                    part.nnz_local.iter().sum::<usize>(),
                    ds.a.nnz(),
                    "{policy:?} lost nonzeros"
                );
            }
        }
        let mp = MeshPartition::build(&ds, Mesh::new(2, 8), Partitioner::Cyclic);
        assert_eq!(mp.rank_nnz().iter().sum::<usize>(), ds.a.nnz());
    }
}

/// The two-objective selector picks a cache-feasible policy whenever one
/// exists, on every profile.
#[test]
fn selector_always_feasible_when_possible() {
    for spec in [DatasetSpec::UrlLike, DatasetSpec::News20Like, DatasetSpec::Rcv1Like] {
        let ds = spec.profile().generate_scaled(0.05, 5);
        let p_c = 16;
        let pick = stats::select_two_objective(&ds.a, p_c, stats::L_CAP_BYTES);
        let all = stats::survey(&ds.a, p_c, stats::L_CAP_BYTES);
        if all.iter().any(|s| s.fits_cache) {
            let picked = all.iter().find(|s| s.policy == pick).unwrap();
            assert!(picked.fits_cache, "{}: picked infeasible {pick:?}", ds.name);
        }
    }
}
