//! Cross-configuration solver equivalences — the algebraic identities the
//! paper's solver family is built on, verified end to end through the
//! distributed engine.

use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::HybridConfig;
use hybrid_sgd::data::{synth, Dataset};
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::partition::Partitioner;
use hybrid_sgd::solvers::{reference, HybridSolver, RunOpts, SolverKind};
use hybrid_sgd::util::Prng;

fn toy(seed: u64, m: usize, n: usize, z: usize, alpha: f64) -> Dataset {
    let mut rng = Prng::new(seed);
    synth::sparse_skewed("eq-toy", m, n, z, alpha, &mut rng)
}

fn opts(bundles: usize) -> RunOpts {
    RunOpts { max_bundles: bundles, eval_every: 0, ..Default::default() }
}

fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol * (1.0 + y.abs()))
}

/// Row-team parallelism is exact: at τ = 1 and p_c = 1, a p-rank FedAvg
/// mesh from a shared start equals a single global mini-batch step with
/// the averaged gradient — iterated, trajectories coincide with the p = 1
/// run when every team sees identical data.
#[test]
fn identical_row_blocks_make_fedavg_equal_sequential() {
    // Duplicate the same 40-row block 4 times so every team's local data
    // (and cyclic sampling) is identical; then FedAvg averaging of equal
    // updates is a no-op and the run must match the single-rank run.
    let base = toy(1, 40, 24, 5, 0.4);
    let mut rows = Vec::new();
    let mut y = Vec::new();
    for _ in 0..4 {
        for r in 0..40 {
            let (ci, cv) = base.a.row(r);
            rows.push((ci.to_vec(), cv.to_vec()));
            y.push(base.y[r]);
        }
    }
    let mut triplets = Vec::new();
    for (i, (ci, cv)) in rows.iter().enumerate() {
        for (k, &c) in ci.iter().enumerate() {
            triplets.push((i, c as usize, cv[k]));
        }
    }
    let ds =
        Dataset { name: "dup".into(), a: hybrid_sgd::sparse::Csr::from_triplets(160, 24, &triplets), y };

    let be = NativeBackend;
    let par = HybridSolver::new(&be).run(
        &ds,
        SolverKind::FedAvg.config(4, None, 1, 8, 3),
        Partitioner::Rows,
        &opts(12),
    );
    let single = HybridSolver::new(&be).run(
        &ds,
        HybridConfig::new(Mesh::new(1, 1), 1, 8, 3),
        Partitioner::Rows,
        &opts(12),
    );
    // Single-rank cyclic sampling walks all 160 rows; the 4-team run walks
    // each 40-row block. Identical blocks ⇒ identical batches ⇒ identical
    // updates after averaging equals any team's update.
    assert!(close(&par.x, &single.x, 1e-10), "fedavg-of-clones != sequential");
}

/// MB-SGD is FedAvg at τ = 1 (paper §4.1: "τ = 1 degenerates to
/// synchronous mini-batch SGD").
#[test]
fn mbsgd_is_fedavg_tau1() {
    let ds = toy(2, 120, 40, 6, 0.5);
    let be = NativeBackend;
    let a = HybridSolver::new(&be).run(
        &ds,
        SolverKind::MbSgd.config(4, None, 1, 8, 99),
        Partitioner::Rows,
        &opts(10),
    );
    let b = HybridSolver::new(&be).run(
        &ds,
        SolverKind::FedAvg.config(4, None, 1, 8, 1),
        Partitioner::Rows,
        &opts(10),
    );
    assert_eq!(a.x, b.x);
}

/// 2D SGD at s = 1, τ = 1 must not depend on the mesh factorization: all
/// meshes of the same p produce the same model when row blocks are the
/// same... which they are only when p_r is fixed; instead verify the
/// column dimension alone never changes the math (fixed p_r, varying p_c).
#[test]
fn column_dimension_never_changes_trajectory() {
    let ds = toy(3, 96, 64, 6, 0.8);
    let be = NativeBackend;
    let reference = HybridSolver::new(&be).run(
        &ds,
        HybridConfig::new(Mesh::new(2, 1), 2, 8, 4),
        Partitioner::Rows,
        &opts(8),
    );
    for p_c in [2usize, 4, 8] {
        for policy in Partitioner::all() {
            let run = HybridSolver::new(&be).run(
                &ds,
                HybridConfig::new(Mesh::new(2, p_c), 2, 8, 4),
                policy,
                &opts(8),
            );
            assert!(
                close(&run.x, &reference.x, 1e-9),
                "p_c={p_c} {policy:?} diverged from p_c=1"
            );
        }
    }
}

/// The s-step reformulation identity at the full-distributed level:
/// HybridSGD (1×4, s=4) equals 4·bundles sequential SGD steps.
#[test]
fn distributed_sstep_matches_sequential_sgd() {
    let ds = toy(4, 80, 32, 5, 0.6);
    let be = NativeBackend;
    let run = HybridSolver::new(&be).run(
        &ds,
        HybridConfig::sstep_corner(4, 4, 8),
        Partitioner::Cyclic,
        &opts(5),
    );
    let (x_ref, _) = reference::minibatch_sgd(&ds, &be, 8, 0.01, 20, 0);
    assert!(close(&run.x, &x_ref, 1e-8), "distributed s-step != sequential SGD");
}

/// Degenerate data must not break any mesh/partitioner combination:
/// single-class labels, empty rows, and a column with no nonzeros.
#[test]
fn degenerate_datasets_run_everywhere() {
    let mut triplets = vec![(0usize, 0usize, 1.0f64)];
    // rows 1..4 empty; column 5 never touched; one heavy column.
    for r in 4..32 {
        triplets.push((r, 1, 0.5));
        triplets.push((r, 2 + (r % 3), -0.25));
    }
    let a = hybrid_sgd::sparse::Csr::from_triplets(32, 8, &triplets);
    let ds = Dataset { name: "degen".into(), a, y: vec![1.0; 32] };
    let be = NativeBackend;
    for mesh in [Mesh::new(1, 2), Mesh::new(2, 2), Mesh::new(4, 1)] {
        for policy in Partitioner::all() {
            let run = HybridSolver::new(&be).run(
                &ds,
                HybridConfig::new(mesh, 2, 4, 2),
                policy,
                &opts(6),
            );
            assert!(run.x.iter().all(|v| v.is_finite()), "{mesh} {policy:?} produced non-finite");
        }
    }
}

/// Determinism across the charging policies: the *trajectory* is identical
/// whether compute time is measured or modeled (timing policy must never
/// leak into the math).
#[test]
fn charging_policy_does_not_affect_math() {
    use hybrid_sgd::comm::Charging;
    let ds = toy(5, 100, 40, 5, 0.5);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 2), 2, 8, 4);
    let mut o1 = opts(8);
    o1.charging = Charging::Modeled;
    let mut o2 = opts(8);
    o2.charging = Charging::Measured;
    let a = HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &o1);
    let b = HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &o2);
    assert_eq!(a.x, b.x);
}
