//! Golden equivalence for the Session solver API:
//!
//! * a `step_bundle()`-driven session is **bit-identical** (weights,
//!   trace, books, `sim_wall`) to the `HybridSolver::run` wrapper across
//!   the overlap × selector × rs_row knob grid (property test);
//! * checkpoint → resume → identical final state, including a checkpoint
//!   taken with a row reduce still in flight under bundle overlap;
//! * the early-stop bugfix: under `OverlapPolicy::Bundle`,
//!   `time_to_target` is read only after the in-flight transfer settles
//!   (regression test);
//! * bound-aware mid-run retuning moves charged books only, never
//!   trajectories;
//! * the bundle Gram strategy knob (`--gram merge|scatter|auto`) is a
//!   host-wall-only knob: weights, traces, walls, and charged books are
//!   bit-identical across all three strategies;
//! * the execution backend (`--backend sim|threads`) is value- and
//!   book-invariant: real threads-as-ranks execution reproduces the
//!   simulated backend bit for bit across the same knob grid, and
//!   checkpoints resume across backends in both directions.

use hybrid_sgd::collectives::SelectorSource;
use hybrid_sgd::comm::{ExecBackend, OverlapPolicy};
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::HybridConfig;
use hybrid_sgd::data::synth;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::metrics::{Phase, PhaseBook};
use hybrid_sgd::partition::Partitioner;
use hybrid_sgd::solvers::{HybridSolver, RetunePolicy, RunOpts, SessionBuilder, SolverRun};
use hybrid_sgd::sparse::GramStrategy;
use hybrid_sgd::util::proptest::{check, Config};
use hybrid_sgd::util::Prng;

const GRAMS: [GramStrategy; 3] = [GramStrategy::Merge, GramStrategy::Scatter, GramStrategy::Auto];

/// Apply a prebuilt [`RunOpts`] through the per-knob builder surface
/// (the whole-struct `.opts(..)` compat path is retired).
fn with_opts<'a>(b: SessionBuilder<'a>, o: &RunOpts) -> SessionBuilder<'a> {
    b.eta(o.eta)
        .max_bundles(o.max_bundles)
        .eval_every(o.eval_every)
        .target_loss(o.target_loss)
        .backend(o.backend)
        .lanes(o.lanes)
        .charging(o.charging)
        .profile(o.profile.clone())
        .algo(o.algo)
        .selector(o.selector)
        .overlap(o.overlap)
        .rs_row(o.rs_row)
        .gram(o.gram)
        .record_timeline(o.timeline)
        .seed(o.seed)
}

fn bits(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Non-metrics books bit-equal (the `Metrics` phase charges measured
/// host wall — nondeterministic between any two runs by design).
fn books_equal(a: &PhaseBook, b: &PhaseBook) -> bool {
    Phase::all().iter().filter(|ph| ph.in_algorithm_total()).all(|&ph| {
        a.mean_charged(ph).to_bits() == b.mean_charged(ph).to_bits()
            && a.mean_wait(ph).to_bits() == b.mean_wait(ph).to_bits()
            && a.mean_hidden(ph).to_bits() == b.mean_hidden(ph).to_bits()
    }) && a.words == b.words
        && a.messages == b.messages
}

fn runs_equal(a: &SolverRun, b: &SolverRun) -> bool {
    bits(&a.x) == bits(&b.x)
        && a.sim_wall.to_bits() == b.sim_wall.to_bits()
        && a.bundles_run == b.bundles_run
        && a.inner_iters == b.inner_iters
        && a.time_to_target.map(f64::to_bits) == b.time_to_target.map(f64::to_bits)
        && a.trace.len() == b.trace.len()
        && a.trace.iter().zip(&b.trace).all(|(p, q)| {
            p.bundles == q.bundles
                && p.iters == q.iters
                && p.sim_time.to_bits() == q.sim_time.to_bits()
                && p.loss.to_bits() == q.loss.to_bits()
        })
        && books_equal(&a.book, &b.book)
}

/// The tentpole golden suite: across mesh shapes, s-step depths,
/// overlap × selector × rs_row × gram, eval cadences, and early-stop
/// targets, a manually stepped session reproduces `HybridSolver::run`
/// exactly.
#[test]
fn prop_step_driven_session_bit_identical_to_run() {
    let mut rng = Prng::new(0x5E5510);
    let ds = synth::sparse_skewed("golden-toy", 160, 48, 5, 0.6, &mut rng);
    let be = NativeBackend;
    check(
        Config { cases: 24, seed: 0x5E5510 },
        "step-driven session == monolithic run, bit for bit",
        |rng| {
            (
                1 + rng.next_below(3),  // p_r
                1 + rng.next_below(4),  // p_c
                1 + rng.next_below(3),  // s
                2 + rng.next_below(7),  // b
                rng.next_below(3),      // tau - s offset
                rng.next_below(2) == 1, // overlap bundle
                rng.next_below(2) == 1, // rs_row
                rng.next_below(2) == 1, // measured selector
                rng.next_below(3),      // eval_every
                rng.next_below(2) == 1, // generous target (early stop path)
                rng.next_below(3),      // gram strategy index
            )
        },
        |&(p_r, p_c, s, b, tau_off, overlap, rs_row, measured, eval_every, target, gram)| {
            let cfg = HybridConfig::new(Mesh::new(p_r, p_c), s, b, s + tau_off);
            let opts = RunOpts {
                max_bundles: 6,
                eval_every,
                overlap: if overlap { OverlapPolicy::Bundle } else { OverlapPolicy::Off },
                rs_row,
                selector: if measured {
                    SelectorSource::Measured
                } else {
                    SelectorSource::Analytic
                },
                // A loose target so some cases exercise the early stop.
                target_loss: if target { Some(0.69) } else { None },
                gram: GRAMS[gram],
                ..Default::default()
            };
            let run = HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &opts);
            let builder = SessionBuilder::new(&be, &ds, cfg).partitioner(Partitioner::Cyclic);
            let mut session = with_opts(builder, &opts).build();
            while !session.is_done() {
                let _ = session.step_bundle();
            }
            runs_equal(&run, &session.finish())
        },
    );
}

/// Checkpoint → resume → identical final weights, trace, books, and
/// wall, across both overlap policies (under `Bundle` the checkpoint
/// carries a posted, unsettled row reduce).
#[test]
fn prop_checkpoint_resume_bit_identical() {
    let mut rng = Prng::new(0xC4EC7);
    let ds = synth::sparse_skewed("ckpt-toy", 140, 40, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let dir = std::env::temp_dir().join(format!("session_equiv_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    check(
        Config { cases: 12, seed: 0xC4EC7 },
        "checkpoint/resume round trip is bit-identical",
        |rng| {
            (
                1 + rng.next_below(3),  // p_r
                1 + rng.next_below(3),  // p_c
                1 + rng.next_below(3),  // s
                2 + rng.next_below(5),  // b
                rng.next_below(2) == 1, // overlap bundle
                rng.next_below(2) == 1, // rs_row
                1 + rng.next_below(5),  // bundles before the checkpoint
                rng.next_below(1 << 16),
            )
        },
        |&(p_r, p_c, s, b, overlap, rs_row, cut, case)| {
            let cfg = HybridConfig::new(Mesh::new(p_r, p_c), s, b, s + 1);
            let opts = RunOpts {
                max_bundles: 7,
                eval_every: 2,
                overlap: if overlap { OverlapPolicy::Bundle } else { OverlapPolicy::Off },
                rs_row,
                ..Default::default()
            };
            let builder = || {
                with_opts(
                    SessionBuilder::new(&be, &ds, cfg).partitioner(Partitioner::Cyclic),
                    &opts,
                )
            };
            let straight = builder().run_to_end();
            let path = dir.join(format!("case_{case}.tsv"));
            let mut first = builder().build();
            for _ in 0..cut {
                let _ = first.step_bundle();
            }
            first.checkpoint(&path).unwrap();
            drop(first);
            let mut resumed = builder().resume(&path).unwrap();
            while !resumed.is_done() {
                let _ = resumed.step_bundle();
            }
            let resumed = resumed.finish();
            std::fs::remove_file(&path).unwrap();
            runs_equal(&straight, &resumed)
        },
    );
    std::fs::remove_dir_all(dir).unwrap();
}

/// Regression (satellite bugfix): stopping early on `target_loss` under
/// bundle overlap must settle the in-flight row transfer *before*
/// `time_to_target` is read — the reported time now includes the exposed
/// remainder and equals the run's final `sim_wall`. The seed read the
/// clock mid-flight and under-reported.
#[test]
fn time_to_target_settles_in_flight_transfer_under_bundle_overlap() {
    let mut rng = Prng::new(0x7A26E7);
    let ds = synth::sparse_skewed("ttt-toy", 200, 48, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 4), 2, 8, 2);
    let run_with = |overlap: OverlapPolicy| {
        let opts = RunOpts {
            max_bundles: 400,
            eval_every: 2,
            eta: 0.1,
            target_loss: Some(0.68),
            overlap,
            ..Default::default()
        };
        HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &opts)
    };
    let off = run_with(OverlapPolicy::Off);
    let bun = run_with(OverlapPolicy::Bundle);
    assert!(off.time_to_target.is_some(), "target must be reachable for the regression probe");
    // Both charging regimes stop at the same bundle with the same model.
    assert_eq!(off.bundles_run, bun.bundles_run);
    assert_eq!(off.x, bun.x);
    // The fixed contract: time-to-target includes the settled in-flight
    // transfer, so it coincides with the final wall in both regimes
    // (the seed's Bundle path reported a smaller, mid-flight clock).
    assert_eq!(off.time_to_target.unwrap().to_bits(), off.sim_wall.to_bits());
    assert_eq!(bun.time_to_target.unwrap().to_bits(), bun.sim_wall.to_bits());
    // Overlap still pays off end to end.
    assert!(bun.sim_wall <= off.sim_wall * (1.0 + 1e-12));
}

/// The bundle Gram strategy knob: runs under `merge`, `scatter`, and
/// `auto` are **fully** bit-identical — weights, traces, walls, charged
/// books, words, messages — across the overlap × rs_row grid (the
/// acceptance pin for the working-set layer: `--gram` moves host wall
/// time only).
#[test]
fn prop_gram_strategy_bit_identical_across_knob_grid() {
    let mut rng = Prng::new(0x62A3);
    let ds = synth::sparse_skewed("gram-toy", 150, 44, 5, 0.6, &mut rng);
    let be = NativeBackend;
    check(
        Config { cases: 12, seed: 0x62A3 },
        "gram merge == scatter == auto, bit for bit",
        |rng| {
            (
                1 + rng.next_below(3),  // p_r
                1 + rng.next_below(4),  // p_c
                2 + rng.next_below(2),  // s >= 2 so the Gram phase runs
                2 + rng.next_below(6),  // b
                rng.next_below(2) == 1, // overlap bundle
                rng.next_below(2) == 1, // rs_row
            )
        },
        |&(p_r, p_c, s, b, overlap, rs_row)| {
            let cfg = HybridConfig::new(Mesh::new(p_r, p_c), s, b, s + 1);
            let run_with = |gram: GramStrategy| {
                let opts = RunOpts {
                    max_bundles: 6,
                    eval_every: 2,
                    overlap: if overlap { OverlapPolicy::Bundle } else { OverlapPolicy::Off },
                    rs_row,
                    gram,
                    ..Default::default()
                };
                HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &opts)
            };
            let merge = run_with(GramStrategy::Merge);
            let scatter = run_with(GramStrategy::Scatter);
            let auto = run_with(GramStrategy::Auto);
            runs_equal(&merge, &scatter) && runs_equal(&merge, &auto)
        },
    );
}

/// Bound-aware mid-run retuning: trajectories bit-identical to the fixed
/// policy, evals/trace unchanged — only the charged books may move.
#[test]
fn bound_aware_retune_is_trajectory_invariant_end_to_end() {
    let mut rng = Prng::new(0x2E7E4E);
    let ds = synth::sparse_skewed("retune-toy", 160, 48, 5, 0.6, &mut rng);
    let be = NativeBackend;
    for (mesh, s, b) in [(Mesh::new(2, 4), 2, 8), (Mesh::new(2, 8), 4, 16), (Mesh::new(1, 4), 3, 6)]
    {
        let cfg = HybridConfig::new(mesh, s, b, s + 1);
        let session = |retune: RetunePolicy| {
            SessionBuilder::new(&be, &ds, cfg)
                .partitioner(Partitioner::Cyclic)
                .max_bundles(9)
                .eval_every(3)
                .retune(retune)
                .build()
        };
        let plain = session(RetunePolicy::Off).run_to_end();
        let mut tuned = session(RetunePolicy::BoundAware { every: 2 });
        while !tuned.is_done() {
            let _ = tuned.step_bundle();
        }
        assert_eq!(tuned.retunes().len(), 4, "{mesh}: checks at bundles 2, 4, 6, 8");
        let tuned = tuned.finish();
        assert_eq!(bits(&tuned.x), bits(&plain.x), "{mesh}: retuning changed the trajectory");
        assert_eq!(tuned.trace.len(), plain.trace.len());
        for (a, b) in tuned.trace.iter().zip(&plain.trace) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{mesh}: retuning changed a loss");
        }
    }
}

/// The tentpole acceptance pin: real threads-as-ranks execution is
/// **bit-identical** to the simulated backend — weights, traces, walls,
/// charged books, words, messages — across the
/// overlap × selector × rs_row × gram knob grid. The collective values
/// come from a real barrier-synchronized shared-memory reduction under
/// `Threads`, yet match `Sim`'s canonical host-thread reduce bit for bit
/// because both accumulate in the same linear team order.
#[test]
fn prop_threads_backend_bit_identical_to_sim() {
    let mut rng = Prng::new(0xBACE);
    let ds = synth::sparse_skewed("backend-toy", 150, 44, 5, 0.6, &mut rng);
    let be = NativeBackend;
    check(
        Config { cases: 16, seed: 0xBACE },
        "threads backend == sim backend, bit for bit",
        |rng| {
            (
                1 + rng.next_below(3),  // p_r
                1 + rng.next_below(4),  // p_c
                1 + rng.next_below(3),  // s
                2 + rng.next_below(6),  // b
                rng.next_below(2) == 1, // overlap bundle
                rng.next_below(2) == 1, // rs_row
                rng.next_below(2) == 1, // measured selector
                rng.next_below(3),      // gram strategy index
                1 + rng.next_below(4),  // lanes (threads pool cap)
            )
        },
        |&(p_r, p_c, s, b, overlap, rs_row, measured, gram, lanes)| {
            let cfg = HybridConfig::new(Mesh::new(p_r, p_c), s, b, s + 1);
            let run_with = |backend: ExecBackend| {
                let opts = RunOpts {
                    max_bundles: 5,
                    eval_every: 2,
                    overlap: if overlap { OverlapPolicy::Bundle } else { OverlapPolicy::Off },
                    rs_row,
                    selector: if measured {
                        SelectorSource::Measured
                    } else {
                        SelectorSource::Analytic
                    },
                    gram: GRAMS[gram],
                    backend,
                    lanes,
                    ..Default::default()
                };
                HybridSolver::new(&be).run(&ds, cfg, Partitioner::Cyclic, &opts)
            };
            let sim = run_with(ExecBackend::Sim);
            let threads = run_with(ExecBackend::Threads);
            runs_equal(&sim, &threads)
        },
    );
}

/// Checkpoints are backend-portable: a session checkpointed under one
/// execution backend resumes under the other, both directions, and the
/// resumed run finishes bit-identical to a straight single-backend run.
/// (The checkpoint schema deliberately records no backend — execution is
/// a property of the resuming process, not of the optimizer state.)
#[test]
fn checkpoint_resumes_across_backends_both_ways() {
    let mut rng = Prng::new(0xC0B0);
    let ds = synth::sparse_skewed("xbackend-toy", 140, 40, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let dir = std::env::temp_dir().join(format!("session_equiv_xbackend_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for (from, to, overlap) in [
        (ExecBackend::Sim, ExecBackend::Threads, OverlapPolicy::Off),
        (ExecBackend::Threads, ExecBackend::Sim, OverlapPolicy::Off),
        (ExecBackend::Sim, ExecBackend::Threads, OverlapPolicy::Bundle),
        (ExecBackend::Threads, ExecBackend::Sim, OverlapPolicy::Bundle),
    ] {
        let cfg = HybridConfig::new(Mesh::new(2, 3), 2, 5, 3);
        let opts = RunOpts { max_bundles: 7, eval_every: 2, overlap, ..Default::default() };
        let builder = |backend: ExecBackend| {
            with_opts(SessionBuilder::new(&be, &ds, cfg).partitioner(Partitioner::Cyclic), &opts)
                .backend(backend)
        };
        let straight = builder(ExecBackend::Sim).run_to_end();
        let path = dir.join(format!("{}_{}_{overlap:?}.tsv", from.name(), to.name()));
        let mut first = builder(from).build();
        for _ in 0..3 {
            let _ = first.step_bundle();
        }
        first.checkpoint(&path).unwrap();
        drop(first);
        let mut resumed = builder(to).resume(&path).unwrap();
        while !resumed.is_done() {
            let _ = resumed.step_bundle();
        }
        let resumed = resumed.finish();
        std::fs::remove_file(&path).unwrap();
        assert!(
            runs_equal(&straight, &resumed),
            "resume {} -> {} under {overlap:?} diverged from the straight run",
            from.name(),
            to.name(),
        );
    }
    std::fs::remove_dir_all(dir).unwrap();
}
