//! Chaos harness for the serve stack's self-healing machinery, driven
//! by the seeded [`FaultPlan`] layer:
//!
//! * **the headline property** — under a plan combining a worker crash,
//!   a corrupted newest checkpoint generation, a straggler stall, and a
//!   severed watch stream, every admitted job still completes, and its
//!   final checkpoint (trajectory *and* charged books) is bit-identical
//!   to a fault-free reference run; the scrape file counts exactly the
//!   faults the plan declares (one retry, one generation fallback, one
//!   fired fault per kind);
//! * **deadlines** — a job admitted with a wall-clock deadline is
//!   stopped at a bundle boundary with the typed `deadline-exceeded`
//!   note once the budget is spent;
//! * **drain escalation** — a drain wedged behind a stuck worker
//!   escalates at `drain_timeout`: the stuck job is forcibly
//!   interrupted with the typed `drain-timeout` note, the daemon never
//!   wedges, and a restart resumes the job from its last durable
//!   checkpoint to a clean finish;
//! * **corruption corpus** — bit-flipped, truncated, count-trimmed, and
//!   future-schema session checkpoints are typed resume errors (never a
//!   panic), as are damaged spool records.
//!
//! The plan for the headline test round-trips through its TSV form
//! first, so the test covers the same loader the `serve --fault-plan`
//! CLI path uses.

use hybrid_sgd::costmodel::HybridConfig;
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::data::{synth, DatasetSpec};
use hybrid_sgd::fault::{corrupt_file, CorruptMode, Fault, FaultPlan};
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::serve::{Client, Daemon, DaemonConfig, JobSpec, JobState, Spool};
use hybrid_sgd::solvers::SessionBuilder;
use hybrid_sgd::util::Prng;
use std::fs;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

fn spool_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("serve_chaos_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn quick_spec(bundles: usize, ckpt_every: usize, seed: u64) -> JobSpec {
    JobSpec {
        dataset: DatasetSpec::Rcv1Like,
        scale: 0.05,
        p: 2,
        bundles,
        eval_every: 3,
        eta: 0.1,
        tau: 10,
        seed,
        target: None,
        ckpt_every,
        deadline: None,
    }
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(120);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Checkpoint lines for the bit-identity compare — same filter as the
/// serve_daemon harness: `book metrics` rows carry measured host wall,
/// and the `checksum` trailer hashes them, so both are excluded; every
/// other row must match byte for byte.
fn ckpt_lines(path: &Path) -> Vec<String> {
    fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
        .lines()
        .filter(|l| !l.starts_with("book\tmetrics\t") && !l.starts_with("checksum\t"))
        .map(|l| l.to_string())
        .collect()
}

// ---------------------------------------------------------------------
// The headline property: chaos run ≡ fault-free run, bit for bit
// ---------------------------------------------------------------------

/// One plan, four fault kinds, two jobs:
///
/// * job 1 (30 bundles, ckpt every 2) — its newest checkpoint is
///   bit-flipped right after the commit at bundle 8, its worker is
///   crashed after bundle 9, and its watch stream is severed after 3
///   frames. Recovery: the retry resumes past the corrupt generation 0
///   (bundle 8) from generation 1 (bundle 6), the client reconnects
///   from its cursor, and the job finishes its full budget.
/// * job 2 (12 bundles, no periodic checkpoints) — stalled 1s after
///   bundle 5, far above both the straggle floor and 8× its own
///   bundle-wall EWMA, so it is flagged `degraded` (observation only:
///   the stall never moves the trajectory).
///
/// Both final checkpoints must equal the ones from an identical
/// fault-free run, and the scrape must count exactly what the plan
/// declares.
#[test]
fn chaos_plan_recovers_every_job_bit_identically() {
    let plan = FaultPlan::new(7)
        .with(Fault::Crash { job: 1, bundle: 9 })
        .with(Fault::CorruptCkpt { job: 1, bundle: 8, mode: CorruptMode::BitFlip })
        .with(Fault::DropConn { job: 1, after_frames: 3 })
        .with(Fault::Straggle { job: 2, bundle: 5, millis: 1000 });

    // Round-trip the plan through its TSV form — the same loader the
    // `serve --fault-plan` CLI path uses.
    let plan_path = std::env::temp_dir()
        .join(format!("serve_chaos_plan_{}.tsv", std::process::id()));
    plan.to_tsv(&plan_path).unwrap();
    let loaded = FaultPlan::from_tsv(&plan_path).unwrap();
    assert_eq!(loaded, plan);
    let _ = fs::remove_file(&plan_path);

    let spec1 = quick_spec(30, 2, 0x5EED);
    let spec2 = quick_spec(12, 0, 0xB0B);

    // Fault-free reference run.
    let ref_spool = spool_dir("ref");
    let daemon = Daemon::start(DaemonConfig::local(&ref_spool)).unwrap();
    let client = Client::new(daemon.addr().to_string());
    let (r1, _) = client.submit(&spec1).unwrap();
    let (r2, _) = client.submit(&spec2).unwrap();
    assert_eq!((r1.id, r2.id), (1, 2));
    assert_eq!(client.watch(1, 0, |_| {}).unwrap().state, JobState::Done);
    assert_eq!(client.watch(2, 0, |_| {}).unwrap().state, JobState::Done);
    client.shutdown().unwrap();
    daemon.wait();
    let ref_ckpt1 = ckpt_lines(&Spool::open(&ref_spool).unwrap().ckpt_path(1));
    let ref_ckpt2 = ckpt_lines(&Spool::open(&ref_spool).unwrap().ckpt_path(2));

    // Chaos run: same specs, same submission order, the plan above.
    let spool = spool_dir("chaos");
    let mut cfg = DaemonConfig::local(&spool);
    cfg.metrics_out = Some(spool.join("chaos.prom"));
    cfg.retry_backoff_ms = 10;
    cfg.faults = Some(loaded);
    let daemon = Daemon::start(cfg).unwrap();
    let client = Client::new(daemon.addr().to_string());
    let (c1, _) = client.submit(&spec1).unwrap();
    let (c2, _) = client.submit(&spec2).unwrap();
    assert_eq!((c1.id, c2.id), (1, 2));

    // Watch job 1 through the severed stream: the typed client retry
    // reconnects from its bundle cursor and still sees the terminal
    // frame. (The retried worker replays bundles 7..9, so duplicates
    // are expected in the log — the cursor only ever moves forward.)
    let mut max_bundle = 0;
    let done1 = client.watch(1, 0, |t| max_bundle = max_bundle.max(t.bundle)).unwrap();
    assert_eq!(done1.state, JobState::Done, "job 1 must recover, note {:?}", done1.note);
    assert_eq!(done1.bundles, 30);
    assert_eq!(max_bundle, 30);
    assert_eq!(done1.note, "", "a recovered job carries no stale panic note");
    let done2 = client.watch(2, 0, |_| {}).unwrap();
    assert_eq!(done2.state, JobState::Done);
    assert_eq!(done2.bundles, 12);

    // The status board tells the recovery story: job 1 spent one unit
    // of its retry budget, job 2 is flagged degraded by the straggle.
    let rows = client.status(None).unwrap();
    let row1 = rows.iter().find(|r| r.id == 1).unwrap();
    let row2 = rows.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(row1.retries, 1, "exactly one crash, one retry");
    assert_eq!(row2.retries, 0);
    assert_eq!(row2.health, "degraded", "the 1s stall must trip the straggle gauge");

    client.shutdown().unwrap();
    daemon.wait();

    // Bit-identity: the chaos trajectory and charged books equal the
    // fault-free ones.
    let spool_h = Spool::open(&spool).unwrap();
    assert_eq!(ckpt_lines(&spool_h.ckpt_path(1)), ref_ckpt1, "job 1 diverged under chaos");
    assert_eq!(ckpt_lines(&spool_h.ckpt_path(2)), ref_ckpt2, "job 2 diverged under chaos");

    // The scrape counts exactly what the plan declares.
    let scrape = fs::read_to_string(spool.join("chaos.prom")).unwrap();
    for needle in [
        "hybridsgd_serve_jobs_done_total 2",
        "hybridsgd_serve_jobs_failed_total 0",
        "hybridsgd_serve_job_retries_total 1",
        "hybridsgd_serve_ckpt_fallbacks_total 1",
        "hybridsgd_serve_faults_injected_total{kind=\"crash\"} 1",
        "hybridsgd_serve_faults_injected_total{kind=\"corrupt-ckpt\"} 1",
        "hybridsgd_serve_faults_injected_total{kind=\"drop-conn\"} 1",
        "hybridsgd_serve_faults_injected_total{kind=\"straggle\"} 1",
        "hybridsgd_serve_job_degraded{job=\"2\"} 1",
    ] {
        assert!(scrape.contains(needle), "scrape missing `{needle}`:\n{scrape}");
    }

    let _ = fs::remove_dir_all(&ref_spool);
    let _ = fs::remove_dir_all(&spool);
}

// ---------------------------------------------------------------------
// Deadlines
// ---------------------------------------------------------------------

/// A job admitted with a tiny wall-clock deadline is stopped at a
/// bundle boundary: `failed` with the typed `deadline-exceeded` note
/// (and the matching counter), not a cancel and not a wedge.
#[test]
fn deadline_exceeded_is_a_typed_failure() {
    let spool = spool_dir("deadline");
    let mut cfg = DaemonConfig::local(&spool);
    cfg.metrics_out = Some(spool.join("deadline.prom"));
    let daemon = Daemon::start(cfg).unwrap();
    let client = Client::new(daemon.addr().to_string());

    let mut spec = quick_spec(100_000, 0, 1);
    spec.deadline = Some(0.3);
    let (row, _) = client.submit(&spec).unwrap();
    let done = client.watch(row.id, 0, |_| {}).unwrap();
    assert_eq!(done.state, JobState::Failed);
    assert_eq!(done.note, "deadline-exceeded");
    assert!(done.bundles < 100_000, "the deadline must cut the budget short");

    // The typed note is durable: a restarted daemon must not resume a
    // deadline-failed job.
    let spool_h = Spool::open(&spool).unwrap();
    let rec = spool_h.load(spool_h.record_path(row.id)).unwrap();
    assert_eq!(rec.state, JobState::Failed);
    assert_eq!(rec.note.as_deref(), Some("deadline-exceeded"));

    client.shutdown().unwrap();
    daemon.wait();
    let scrape = fs::read_to_string(spool.join("deadline.prom")).unwrap();
    assert!(
        scrape.contains("hybridsgd_serve_jobs_deadline_exceeded_total 1"),
        "deadline counter missing:\n{scrape}"
    );
    let _ = fs::remove_dir_all(&spool);
}

// ---------------------------------------------------------------------
// Drain escalation
// ---------------------------------------------------------------------

/// A drain wedged behind a stuck worker (here: a 60s injected straggle)
/// escalates at `drain_timeout`: the job is forcibly interrupted with
/// the typed `drain-timeout` note, `wait` returns promptly with the
/// forced id, and a restarted daemon resumes the job from its last
/// durable checkpoint to a clean finish.
#[test]
fn drain_timeout_forces_stuck_jobs_and_restart_recovers_them() {
    let spool = spool_dir("drain");
    let mut cfg = DaemonConfig::local(&spool);
    cfg.drain_timeout = Some(Duration::from_millis(300));
    cfg.faults =
        Some(FaultPlan::new(1).with(Fault::Straggle { job: 1, bundle: 3, millis: 60_000 }));
    let daemon = Daemon::start(cfg).unwrap();
    let client = Client::new(daemon.addr().to_string());

    let (row, _) = client.submit(&quick_spec(40, 2, 2)).unwrap();
    assert_eq!(row.id, 1);
    // Let the worker commit the bundle-2 checkpoint and walk into the
    // 60s stall at bundle 3.
    wait_until("job 1 stuck in the straggle", || {
        client.status(Some(1)).unwrap()[0].bundles >= 3
    });

    let t0 = Instant::now();
    daemon.shutdown();
    let report = daemon.wait();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "escalation must beat the 60s stall, took {:?}",
        t0.elapsed()
    );
    assert_eq!(report.forced, vec![1], "the stuck job must be forced");
    assert_eq!(report.note(), Some("drain-timeout"));

    let spool_h = Spool::open(&spool).unwrap();
    let rec = spool_h.load(spool_h.record_path(1)).unwrap();
    assert_eq!(rec.state, JobState::Interrupted);
    assert_eq!(rec.note.as_deref(), Some("drain-timeout"));

    // Restart without the fault plan: the forced job resumes from its
    // last durable checkpoint — the crash contract — and finishes.
    let daemon = Daemon::start(DaemonConfig::local(&spool)).unwrap();
    let client = Client::new(daemon.addr().to_string());
    let done = client.watch(1, 0, |_| {}).unwrap();
    assert_eq!(done.state, JobState::Done);
    assert_eq!(done.bundles, 40);
    assert_eq!(done.note, "", "the drain-timeout note must not outlive recovery");
    client.shutdown().unwrap();
    daemon.wait();
    let _ = fs::remove_dir_all(&spool);
}

// ---------------------------------------------------------------------
// Corruption corpus: typed errors, never a panic
// ---------------------------------------------------------------------

/// Every way a checkpoint can rot on disk — a flipped bit, a torn
/// write, a trimmed tail, a future schema — is a typed `InvalidData`
/// resume error. The daemon's generation fallback is built on exactly
/// this property: corruption must be *detected*, not survived by luck.
#[test]
fn corrupted_session_checkpoints_are_typed_resume_errors() {
    let dir = std::env::temp_dir().join(format!("serve_chaos_corpus_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();

    let mut rng = Prng::new(0xC0FFEE);
    let ds = synth::sparse_skewed("chaos-corpus", 140, 40, 5, 0.6, &mut rng);
    let be = NativeBackend;
    let cfg = HybridConfig::new(Mesh::new(2, 2), 2, 6, 2);
    let builder = || SessionBuilder::new(&be, &ds, cfg).max_bundles(6).eval_every(2);

    let good = dir.join("good.tsv");
    let mut session = builder().build();
    for _ in 0..3 {
        let _ = session.step_bundle();
    }
    session.checkpoint(&good).unwrap();
    builder().resume(&good).expect("the pristine checkpoint must resume");
    let text = fs::read_to_string(&good).unwrap();
    // The corpus variants that need hand-editing strip the checksum
    // trailer first, so they probe the guards *behind* it (pre-v3 files
    // have no trailer and rely on those guards alone).
    let stripped: String = text
        .lines()
        .filter(|l| !l.starts_with("checksum\t"))
        .map(|l| format!("{l}\n"))
        .collect();
    assert!(stripped.len() < text.len(), "v3 checkpoints end in a checksum trailer");

    let bad = dir.join("bad.tsv");

    // 1. One flipped bit in the body: caught by the checksum trailer.
    fs::copy(&good, &bad).unwrap();
    corrupt_file(&bad, CorruptMode::BitFlip, 7).unwrap();
    let err = builder().resume(&bad).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("checksum"), "bit-flip: {err}");

    // 2. A torn write (file cut to two thirds): typed, never a panic.
    fs::copy(&good, &bad).unwrap();
    corrupt_file(&bad, CorruptMode::Truncate, 7).unwrap();
    let err = builder().resume(&bad).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "truncate: {err}");

    // 3. A trimmed tail on a trailer-less file: the declared-count
    //    guards name the truncation.
    let mut lines: Vec<&str> = stripped.lines().collect();
    lines.pop();
    fs::write(&bad, lines.join("\n") + "\n").unwrap();
    let err = builder().resume(&bad).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("truncated"), "trimmed tail: {err}");

    // 4. A future schema is rejected by name, not mis-parsed.
    fs::write(&bad, stripped.replace("\tschema\t3", "\tschema\t9")).unwrap();
    let err = builder().resume(&bad).unwrap_err();
    assert!(err.to_string().contains("newer than this build"), "future schema: {err}");

    let _ = fs::remove_dir_all(&dir);
}

/// The spool's job records get the same posture: a torn record is a
/// typed load error (the daemon refuses to silently drop or mangle a
/// spooled job), never a panic.
#[test]
fn corrupted_spool_records_are_typed_load_errors() {
    let spool = Spool::open(spool_dir("spool_corpus")).unwrap();
    let daemon_dir = spool.dir().to_path_buf();

    // A real record, written by the daemon itself.
    let daemon = Daemon::start(DaemonConfig::local(&daemon_dir)).unwrap();
    let client = Client::new(daemon.addr().to_string());
    let (row, _) = client.submit(&quick_spec(4, 0, 3)).unwrap();
    assert_eq!(client.watch(row.id, 0, |_| {}).unwrap().state, JobState::Done);
    client.shutdown().unwrap();
    daemon.wait();

    let path = spool.record_path(row.id);
    spool.load(&path).expect("the pristine record must load");
    corrupt_file(&path, CorruptMode::Truncate, 7).unwrap();
    let err = spool.load(&path).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "torn record: {err}");
    // And a scan over the damaged spool fails loudly instead of
    // dropping the job.
    assert!(spool.scan().is_err(), "scan must surface the torn record");
    let _ = fs::remove_dir_all(&daemon_dir);
}
