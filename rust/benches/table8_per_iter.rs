//! Bench target regenerating the paper's Table 8 — per-iteration runtime at best mesh.
//!
//! Effort via `HYBRID_SGD_EFFORT=quick|full` (default quick). Rows print
//! to stdout; machine-readable TSV lands under `results/`.

use hybrid_sgd::experiments::{table8, Effort};
use std::time::Instant;

fn main() {
    let effort = Effort::from_env();
    let t0 = Instant::now();
    let table = table8::run(effort);
    let overlap = table8::overlap_gain(effort);
    let wall = t0.elapsed().as_secs_f64();
    println!("== Table 8 — per-iteration runtime at best mesh ==");
    println!("{}", table.render());
    println!("== Table 8b — compute/communication overlap gain (--overlap bundle) ==");
    println!("{}", overlap.render());
    println!("(effort {effort:?}, generated in {wall:.1}s; TSV under results/)");
}
