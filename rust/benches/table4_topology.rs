//! Bench target regenerating the paper's Table 4 — topology rule vs
//! empirical best mesh — plus the collective-algorithm sweep the pluggable
//! collectives layer adds: charged Allreduce time per algorithm across
//! every mesh aspect ratio of each Table 4 row, with the auto selector's
//! per-collective picks.
//!
//! Effort via `HYBRID_SGD_EFFORT=quick|full` (default quick). Rows print
//! to stdout; machine-readable TSV lands under `results/`
//! (`table4_topology.tsv` and `table4_algo_sweep.tsv`).

use hybrid_sgd::experiments::{table4, Effort};
use std::time::Instant;

fn main() {
    let effort = Effort::from_env();

    // Pure cost-model arithmetic first: the algorithm × mesh sweep shows
    // where the tuning-table crossovers sit before any solver runs.
    let t0 = Instant::now();
    let sweep = table4::algo_sweep();
    println!("== Table 4 extension — charged Allreduce time by collective algorithm ==");
    println!("{}", sweep.render());
    println!("(per-bundle row + tau-amortized column Allreduce, paper-scale shapes)");
    println!();

    // Then the empirical mesh race behind the paper's Table 4 rows.
    let table = table4::run(effort);
    let wall = t0.elapsed().as_secs_f64();
    println!("== Table 4 — topology rule vs empirical best mesh ==");
    println!("{}", table.render());
    println!("(effort {effort:?}, generated in {wall:.1}s; TSV under results/)");
}
