//! Bench target regenerating the paper's Figure 3 — runtime vs column-skew exponent.
//!
//! Effort via `HYBRID_SGD_EFFORT=quick|full` (default quick). Rows print
//! to stdout; machine-readable TSV lands under `results/`.

use hybrid_sgd::experiments::{fig3, Effort};
use std::time::Instant;

fn main() {
    let effort = Effort::from_env();
    let t0 = Instant::now();
    let table = fig3::run(effort);
    let wall = t0.elapsed().as_secs_f64();
    println!("== Figure 3 — runtime vs column-skew exponent ==");
    println!("{}", table.render());
    println!("(effort {effort:?}, generated in {wall:.1}s; TSV under results/)");
}
