//! Bench target regenerating the paper's Figure 4 — predicted vs measured per-iteration.
//!
//! Effort via `HYBRID_SGD_EFFORT=quick|full` (default quick). Rows print
//! to stdout; machine-readable TSV lands under `results/`.

use hybrid_sgd::experiments::{fig4, Effort};
use std::time::Instant;

fn main() {
    let effort = Effort::from_env();
    let t0 = Instant::now();
    let table = fig4::run(effort);
    let wall = t0.elapsed().as_secs_f64();
    println!("== Figure 4 — predicted vs measured per-iteration ==");
    println!("{}", table.render());
    println!("(effort {effort:?}, generated in {wall:.1}s; TSV under results/)");
}
