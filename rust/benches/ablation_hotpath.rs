//! Hot-path micro-ablations: the kernel-level choices DESIGN.md calls out.
//!
//! * sparse Gram: merge-join vs scatter/gather (the `syrkd` analogue);
//! * s-step correction: native Rust vs the XLA/PJRT artifact (per-call
//!   overhead of the AOT path);
//! * SpMV forward vs transpose-scatter throughput;
//! * 2D partition assembly cost (the load-time price of `select_columns`).
//!
//! Prints ns/op medians; drives the §Perf log in EXPERIMENTS.md.

use hybrid_sgd::compute::{ComputeBackend, NativeBackend};
use hybrid_sgd::data::synth;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::partition::{MeshPartition, Partitioner};
use hybrid_sgd::runtime::XlaBackend;
use hybrid_sgd::sparse::{gram, Csr};
use hybrid_sgd::util::stats::median;
use hybrid_sgd::util::{Prng, Table};
use std::time::Instant;

fn time_op<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    median(&samples)
}

fn main() {
    let mut rng = Prng::new(0xAB1A);
    let mut table = Table::new(&["op", "config", "median time", "note"]);

    // --- Gram: merge vs scatter ------------------------------------------
    let a = Csr::random(4096, 8192, 64, &mut rng);
    for &q in &[32usize, 128] {
        let ids: Vec<usize> = (0..q).collect();
        let mut out = vec![0.0; q * q];
        let t_merge = time_op(20, || gram::gram_lower(&a, &ids, &mut out));
        let mut scratch = vec![0.0; a.cols()];
        let t_scatter =
            time_op(20, || gram::gram_lower_scatter(&a, &ids, &mut scratch, &mut out));
        table.row(&[
            "gram merge".into(),
            format!("q={q} zbar=64"),
            fmt(t_merge),
            String::new(),
        ]);
        table.row(&[
            "gram scatter".into(),
            format!("q={q} zbar=64"),
            fmt(t_scatter),
            format!("{:.2}x vs merge", t_merge / t_scatter),
        ]);
    }

    // --- SpMV forward vs transpose ---------------------------------------
    let batch: Vec<usize> = (0..128).collect();
    let x = vec![1.0f64; a.cols()];
    let mut v = vec![0.0f64; batch.len()];
    let t_fwd = time_op(50, || a.spmv_rows(&batch, &x, &mut v));
    let coeff = vec![0.5f64; batch.len()];
    let mut acc = vec![0.0f64; a.cols()];
    let t_tsp = time_op(50, || a.t_spmv_rows_acc(&batch, &coeff, &mut acc));
    table.row(&["spmv fwd".into(), "b=128 zbar=64".into(), fmt(t_fwd), String::new()]);
    table.row(&[
        "spmv transpose".into(),
        "b=128 zbar=64".into(),
        fmt(t_tsp),
        format!("{:.2}x vs fwd", t_tsp / t_fwd),
    ]);

    // --- correction: native vs XLA ----------------------------------------
    let native = NativeBackend;
    for &(s, b) in &[(4usize, 32usize), (8, 64)] {
        let q = s * b;
        let y: Vec<f64> = (0..q * 16).map(|_| rng.next_gaussian()).collect();
        let mut g = vec![0.0; q * q];
        for i in 0..q {
            for l in 0..=i {
                g[i * q + l] = (0..16).map(|c| y[i * 16 + c] * y[l * 16 + c]).sum();
            }
        }
        let vv: Vec<f64> = (0..q).map(|_| rng.next_gaussian()).collect();
        let mut z = vec![0.0; q];
        let t_native =
            time_op(50, || native.sstep_correct(s, b, &g, &vv, 1e-3, &mut z));
        table.row(&[
            "correction native".into(),
            format!("s={s} b={b}"),
            fmt(t_native),
            String::new(),
        ]);
        if let Ok(xla) = XlaBackend::load_default() {
            let t_xla = time_op(50, || xla.sstep_correct(s, b, &g, &vv, 1e-3, &mut z));
            table.row(&[
                "correction xla".into(),
                format!("s={s} b={b}"),
                fmt(t_xla),
                format!("{:.1}x vs native (per-call PJRT overhead)", t_xla / t_native),
            ]);
        }
    }

    // --- partition assembly -----------------------------------------------
    let mut rng2 = Prng::new(7);
    let ds = synth::sparse_skewed("bench", 8192, 16384, 64, 1.0, &mut rng2);
    for &(p_r, p_c) in &[(4usize, 16usize), (4, 64)] {
        let t_build = time_op(5, || {
            let mp = MeshPartition::build(&ds, Mesh::new(p_r, p_c), Partitioner::Cyclic);
            std::hint::black_box(mp.blocks.len());
        });
        table.row(&[
            "mesh partition build".into(),
            format!("{p_r}x{p_c}, nnz={}", ds.a.nnz()),
            fmt(t_build),
            String::new(),
        ]);
    }

    println!("== hot-path ablations ==");
    println!("{}", table.render());
}

fn fmt(t: f64) -> String {
    hybrid_sgd::util::table::fmt_time(t)
}
