//! Hot-path micro-ablations: the kernel-level choices DESIGN.md calls out.
//!
//! * **bundle working-set layer** (the PR 5 tentpole): indirect kernels
//!   (`row_ids` indirection into the full CSR block — the seed hot path)
//!   vs the gathered kernels on a materialized `BundleCsr` stack, on the
//!   4096×8192 synthetic config: gather cost, per-kernel old-vs-new rows,
//!   and the full bundle pipeline (SpMV → Gram → transpose-scatter);
//! * sparse Gram strategies: merge-join vs scatter/gather (the `syrkd`
//!   analogue), including the z̄ sweep across the `GramStrategy::Auto`
//!   density crossover;
//! * s-step correction: the seed scalar recurrence vs the register-tiled
//!   fused kernel, and native vs the XLA/PJRT artifact (per-call overhead
//!   of the AOT path);
//! * 2D partition assembly cost (the load-time price of `select_columns`).
//!
//! Prints ns/op medians (`tools/collect_bench.py` folds the time and
//! `N.NNx` ratio tokens into `BENCH_ci.json`); drives the §Perf log in
//! EXPERIMENTS.md. A trailing `obs::summary` block reports one small
//! end-to-end session (per-phase charged/wait/hidden, traffic) as
//! versioned `summary`-prefixed rows the collector also folds in.

use hybrid_sgd::compute::{ComputeBackend, NativeBackend};
use hybrid_sgd::data::synth;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::partition::{MeshPartition, Partitioner};
use hybrid_sgd::runtime::XlaBackend;
use hybrid_sgd::sparse::{gram, BundleCsr, Csr, GRAM_MERGE_MAX_ZBAR};
use hybrid_sgd::util::stats::median;
use hybrid_sgd::util::{Prng, Table};
use std::time::Instant;

fn time_op<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    median(&samples)
}

/// The seed scalar s-step correction, kept verbatim as the old-kernel
/// baseline for the tiled backend kernel.
fn sstep_correct_scalar(s: usize, b: usize, g: &[f64], v: &[f64], eta_over_b: f64, z: &mut [f64]) {
    let q = s * b;
    let mut t = vec![0.0f64; b];
    for j in 0..s {
        let row0 = j * b;
        for i in 0..b {
            let gi = &g[(row0 + i) * q..(row0 + i) * q + row0];
            let mut acc = 0.0;
            for (gv, zv) in gi.iter().zip(&z[..row0]) {
                acc += gv * zv;
            }
            t[i] = v[row0 + i] + eta_over_b * acc;
        }
        for i in 0..b {
            z[row0 + i] = if t[i] > 700.0 { 0.0 } else { 1.0 / (1.0 + t[i].exp()) };
        }
    }
}

fn main() {
    let mut rng = Prng::new(0xAB1A);
    let mut table = Table::new(&["op", "config", "median time", "note"]);

    // --- Gram: merge vs scatter (contiguous ids, the seed rows) ----------
    let a = Csr::random(4096, 8192, 64, &mut rng);
    for &q in &[32usize, 128] {
        let ids: Vec<usize> = (0..q).collect();
        let mut out = vec![0.0; q * q];
        let t_merge = time_op(20, || gram::gram_lower(&a, &ids, &mut out));
        let mut scratch = vec![0.0; a.cols()];
        let t_scatter =
            time_op(20, || gram::gram_lower_scatter(&a, &ids, &mut scratch, &mut out));
        table.row(&[
            "gram merge".into(),
            format!("q={q} zbar=64"),
            fmt(t_merge),
            String::new(),
        ]);
        table.row(&[
            "gram scatter".into(),
            format!("q={q} zbar=64"),
            fmt(t_scatter),
            format!("{:.2}x vs merge", t_merge / t_scatter),
        ]);
    }

    // --- bundle working-set layer: indirect vs gathered -------------------
    // Strided sample (the bench stand-in for rows spread across the block)
    // on the same 4096×8192 zbar=64 config; each kernel is timed through
    // the `row_ids` indirection (old) and on the materialized stack (new),
    // then the whole bundle pipeline including the gather itself.
    for &q in &[128usize, 512] {
        let ids: Vec<usize> = (0..q).map(|k| (k * 31) % 4096).collect();
        let x = vec![1.0f64; a.cols()];
        let mut v = vec![0.0f64; q];
        let coeff = vec![0.5f64; q];
        let mut acc = vec![0.0f64; a.cols()];
        let mut g = vec![0.0f64; q * q];
        let mut scratch = vec![0.0f64; a.cols()];
        let mut y = BundleCsr::new();
        y.gather(&a, &ids); // steady-state capacity before timing

        let t_gather = time_op(30, || y.gather(&a, &ids));
        table.row(&[
            "bundle gather".into(),
            format!("q={q} zbar=64"),
            fmt(t_gather),
            "once per bundle, amortized over all kernels".into(),
        ]);

        let t_spmv_ind = time_op(30, || a.spmv_rows(&ids, &x, &mut v));
        let t_spmv_gat = time_op(30, || y.spmv(&x, &mut v));
        table.row(&[
            "spmv indirect".into(),
            format!("q={q} zbar=64"),
            fmt(t_spmv_ind),
            String::new(),
        ]);
        table.row(&[
            "spmv gathered".into(),
            format!("q={q} zbar=64"),
            fmt(t_spmv_gat),
            format!("{:.2}x vs indirect", t_spmv_ind / t_spmv_gat),
        ]);

        let t_gram_ind =
            time_op(10, || gram::gram_lower_scatter(&a, &ids, &mut scratch, &mut g));
        let t_gram_gat =
            time_op(10, || gram::gram_lower_scatter_gathered(&y, &mut scratch, &mut g));
        table.row(&[
            "gram indirect".into(),
            format!("q={q} zbar=64 scatter"),
            fmt(t_gram_ind),
            String::new(),
        ]);
        table.row(&[
            "gram gathered".into(),
            format!("q={q} zbar=64 scatter"),
            fmt(t_gram_gat),
            format!("{:.2}x vs indirect", t_gram_ind / t_gram_gat),
        ]);

        let t_tsp_ind = time_op(30, || a.t_spmv_rows_acc(&ids, &coeff, &mut acc));
        let t_tsp_gat = time_op(30, || y.t_spmv_acc(&coeff, &mut acc));
        table.row(&[
            "t_spmv indirect".into(),
            format!("q={q} zbar=64"),
            fmt(t_tsp_ind),
            String::new(),
        ]);
        table.row(&[
            "t_spmv gathered".into(),
            format!("q={q} zbar=64"),
            fmt(t_tsp_gat),
            format!("{:.2}x vs indirect", t_tsp_ind / t_tsp_gat),
        ]);

        // The acceptance row: one whole bundle's kernels, indirect vs
        // gather-then-gathered (the gather is *inside* the new timing, so
        // the ratio is the end-to-end win, not a cherry-pick).
        let t_pipe_ind = time_op(10, || {
            a.spmv_rows(&ids, &x, &mut v);
            gram::gram_lower_scatter(&a, &ids, &mut scratch, &mut g);
            a.t_spmv_rows_acc(&ids, &coeff, &mut acc);
        });
        let t_pipe_gat = time_op(10, || {
            y.gather(&a, &ids);
            y.spmv(&x, &mut v);
            gram::gram_lower_scatter_gathered(&y, &mut scratch, &mut g);
            y.t_spmv_acc(&coeff, &mut acc);
        });
        table.row(&[
            "bundle pipeline indirect".into(),
            format!("q={q} zbar=64"),
            fmt(t_pipe_ind),
            String::new(),
        ]);
        table.row(&[
            "bundle pipeline gathered".into(),
            format!("q={q} zbar=64"),
            fmt(t_pipe_gat),
            format!("{:.2}x vs indirect (incl. gather)", t_pipe_ind / t_pipe_gat),
        ]);
    }

    // --- Gram strategy crossover: z̄ sweep across GramStrategy::Auto ------
    // Merge vs scatter on the gathered stack per density; the winner flips
    // around the shipped GRAM_MERGE_MAX_ZBAR constant — these rows are the
    // measured check of that constant on this machine.
    {
        let q = 128usize;
        let mut g = vec![0.0f64; q * q];
        for &zbar in &[2usize, 4, 8, 16, 32, 64] {
            let mut rngz = Prng::new(0xC705 + zbar as u64);
            let az = Csr::random(4096, 8192, zbar, &mut rngz);
            let ids: Vec<usize> = (0..q).map(|k| (k * 31) % 4096).collect();
            let mut y = BundleCsr::new();
            y.gather(&az, &ids);
            let mut scratch = vec![0.0f64; az.cols()];
            let t_merge = time_op(10, || gram::gram_lower_gathered(&y, &mut g));
            let t_scatter =
                time_op(10, || gram::gram_lower_scatter_gathered(&y, &mut scratch, &mut g));
            let auto_pick = if (zbar as f64) < GRAM_MERGE_MAX_ZBAR { "merge" } else { "scatter" };
            table.row(&[
                "gram crossover".into(),
                format!("q={q} zbar={zbar}"),
                fmt(t_merge.min(t_scatter)),
                format!(
                    "merge/scatter {:.2}x, auto picks {auto_pick}",
                    t_merge / t_scatter
                ),
            ]);
        }
    }

    // --- SpMV forward vs transpose ---------------------------------------
    let batch: Vec<usize> = (0..128).collect();
    let x = vec![1.0f64; a.cols()];
    let mut v = vec![0.0f64; batch.len()];
    let t_fwd = time_op(50, || a.spmv_rows(&batch, &x, &mut v));
    let coeff = vec![0.5f64; batch.len()];
    let mut acc = vec![0.0f64; a.cols()];
    let t_tsp = time_op(50, || a.t_spmv_rows_acc(&batch, &coeff, &mut acc));
    table.row(&["spmv fwd".into(), "b=128 zbar=64".into(), fmt(t_fwd), String::new()]);
    table.row(&[
        "spmv transpose".into(),
        "b=128 zbar=64".into(),
        fmt(t_tsp),
        format!("{:.2}x vs fwd", t_tsp / t_fwd),
    ]);

    // --- correction: seed scalar vs tiled, and native vs XLA ---------------
    let native = NativeBackend;
    for &(s, b) in &[(4usize, 32usize), (8, 64)] {
        let q = s * b;
        let y: Vec<f64> = (0..q * 16).map(|_| rng.next_gaussian()).collect();
        let mut g = vec![0.0; q * q];
        for i in 0..q {
            for l in 0..=i {
                g[i * q + l] = (0..16).map(|c| y[i * 16 + c] * y[l * 16 + c]).sum();
            }
        }
        let vv: Vec<f64> = (0..q).map(|_| rng.next_gaussian()).collect();
        let mut z = vec![0.0; q];
        let t_scalar =
            time_op(50, || sstep_correct_scalar(s, b, &g, &vv, 1e-3, &mut z));
        table.row(&[
            "correction scalar (seed)".into(),
            format!("s={s} b={b}"),
            fmt(t_scalar),
            String::new(),
        ]);
        let t_native =
            time_op(50, || native.sstep_correct(s, b, &g, &vv, 1e-3, &mut z));
        table.row(&[
            "correction tiled".into(),
            format!("s={s} b={b}"),
            fmt(t_native),
            format!("{:.2}x vs scalar (4-wide tile, fused sigmoid)", t_scalar / t_native),
        ]);
        if let Ok(xla) = XlaBackend::load_default() {
            let t_xla = time_op(50, || xla.sstep_correct(s, b, &g, &vv, 1e-3, &mut z));
            table.row(&[
                "correction xla".into(),
                format!("s={s} b={b}"),
                fmt(t_xla),
                format!("{:.1}x vs native (per-call PJRT overhead)", t_xla / t_native),
            ]);
        }
    }

    // --- partition assembly -----------------------------------------------
    let mut rng2 = Prng::new(7);
    let ds = synth::sparse_skewed("bench", 8192, 16384, 64, 1.0, &mut rng2);
    for &(p_r, p_c) in &[(4usize, 16usize), (4, 64)] {
        let t_build = time_op(5, || {
            let mp = MeshPartition::build(&ds, Mesh::new(p_r, p_c), Partitioner::Cyclic);
            std::hint::black_box(mp.blocks.len());
        });
        table.row(&[
            "mesh partition build".into(),
            format!("{p_r}x{p_c}, nnz={}", ds.a.nnz()),
            fmt(t_build),
            String::new(),
        ]);
    }

    println!("== hot-path ablations ==");
    println!("{}", table.render());

    // One small end-to-end session, reported as obs::summary rows: the
    // kernel medians above are host wall; these are the simulated-clock
    // books the kernels feed.
    let mut rng3 = Prng::new(3);
    let sds = synth::sparse_skewed("ablation-e2e", 384, 768, 24, 1.0, &mut rng3);
    let cfg = hybrid_sgd::costmodel::HybridConfig::new(Mesh::new(2, 4), 4, 8, 8);
    let run = hybrid_sgd::solvers::SessionBuilder::new(&NativeBackend, &sds, cfg)
        .max_bundles(6)
        .run_to_end();
    println!("== run summary (obs) ==");
    print!("{}", hybrid_sgd::obs::RunSummary::from_run(&run).render());
}

fn fmt(t: f64) -> String {
    hybrid_sgd::util::table::fmt_time(t)
}
