//! Bench target regenerating the paper's Table 10 — phase breakdown, url 4x64.
//!
//! Effort via `HYBRID_SGD_EFFORT=quick|full` (default quick). Rows print
//! to stdout; machine-readable TSV lands under `results/`. A trailing
//! `obs::summary` block reports the same breakdown as versioned
//! `summary`-prefixed TSV rows, which `tools/collect_bench.py` folds
//! into `BENCH_ci.json` (per-phase charged/wait/hidden ride the CI
//! trajectory as absolute numbers). The summary run executes under the
//! threads backend, so the block also carries per-phase `measured` wall
//! rows — the analytic model scored against this host's real clock.

use hybrid_sgd::comm::ExecBackend;
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::HybridConfig;
use hybrid_sgd::data::{synth, DatasetSpec};
use hybrid_sgd::experiments::{table10, Effort};
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::metrics::Phase;
use hybrid_sgd::obs::RunSummary;
use hybrid_sgd::solvers::SessionBuilder;
use hybrid_sgd::util::Prng;
use std::time::Instant;

fn main() {
    let effort = Effort::from_env();
    let t0 = Instant::now();
    let table = table10::run(effort);
    let wall = t0.elapsed().as_secs_f64();
    println!("== Table 10 — phase breakdown, url 4x64 ==");
    println!("{}", table.render());
    println!("(effort {effort:?}, generated in {wall:.1}s; TSV under results/)");

    // The same breakdown as machine-readable summary rows: one small
    // url-like run on the paper's 4-wide × row-team shape (scaled down so
    // the block stays cheap at quick effort).
    let ds = match effort {
        Effort::Quick => {
            let mut rng = Prng::new(10);
            synth::sparse_skewed("url-bench", 512, 1024, 24, 1.2, &mut rng)
        }
        Effort::Full => DatasetSpec::UrlLike.profile().generate_scaled(0.05, 42),
    };
    let cfg = HybridConfig::new(Mesh::new(4, 8), 4, 8, 10);
    let run = SessionBuilder::new(&NativeBackend, &ds, cfg).max_bundles(8).run_to_end();

    // Model-fidelity check: the same run under the threads backend, where
    // collectives execute as real shared-memory reductions. The charged
    // books are bit-identical to the simulated run by construction; the
    // measured column is real wall clock, so the ratio scores the analytic
    // model against this host. The summary block below is the one
    // `collect_bench.py` keeps (last block wins), which folds the
    // per-phase `measured` rows into `BENCH_ci.json`.
    let t1 = Instant::now();
    let treal = SessionBuilder::new(&NativeBackend, &ds, cfg)
        .backend(ExecBackend::Threads)
        .max_bundles(8)
        .run_to_end();
    let twall = t1.elapsed().as_secs_f64();
    assert_eq!(
        run.book.algorithm_total().to_bits(),
        treal.book.algorithm_total().to_bits(),
        "threads backend must charge identically to the simulator"
    );
    println!("== charged vs measured (threads backend) ==");
    println!("{:<16}  {:>14}  {:>14}", "phase", "charged s", "measured s");
    for ph in Phase::all() {
        if !ph.in_algorithm_total() {
            continue;
        }
        println!(
            "{:<16}  {:>14.6}  {:>14.6}",
            ph.name(),
            treal.book.mean_charged(ph),
            treal.measured.mean_charged(ph)
        );
    }
    println!("(threads run generated in {twall:.1}s)");
    println!("== run summary (obs) ==");
    print!("{}", RunSummary::from_run(&treal).render());
}
