//! Bench target regenerating the paper's Table 10 — phase breakdown, url 4x64.
//!
//! Effort via `HYBRID_SGD_EFFORT=quick|full` (default quick). Rows print
//! to stdout; machine-readable TSV lands under `results/`.

use hybrid_sgd::experiments::{table10, Effort};
use std::time::Instant;

fn main() {
    let effort = Effort::from_env();
    let t0 = Instant::now();
    let table = table10::run(effort);
    let wall = t0.elapsed().as_secs_f64();
    println!("== Table 10 — phase breakdown, url 4x64 ==");
    println!("{}", table.render());
    println!("(effort {effort:?}, generated in {wall:.1}s; TSV under results/)");
}
