//! Bench target regenerating the paper's Table 7 — measured alpha/beta/gamma.
//!
//! Effort via `HYBRID_SGD_EFFORT=quick|full` (default quick). Rows print
//! to stdout; machine-readable TSV lands under `results/`.

use hybrid_sgd::experiments::{table7, Effort};
use std::time::Instant;

fn main() {
    let effort = Effort::from_env();
    let t0 = Instant::now();
    let table = table7::run(effort);
    let crossovers = table7::selector_crossovers(effort);
    let wall = t0.elapsed().as_secs_f64();
    println!("== Table 7 — measured alpha/beta/gamma ==");
    println!("{}", table.render());
    println!("== Table 7b — selector crossovers, measured per-algorithm curves vs analytic ==");
    println!("{}", crossovers.render());
    println!("(effort {effort:?}, generated in {wall:.1}s; TSV under results/)");
}
