//! The measured collective selector, end to end: fit this host's
//! per-algorithm Allreduce curves (the paper's §7.1 microbenchmark
//! methodology applied per schedule), persist them through the TSV
//! profile, diff the measured tuning table against the analytic Hockney
//! envelope, and show that switching the selector source moves charged
//! books only — trajectories stay bit-identical. Finishes with the
//! bound-aware pick: the overlap analyzer's bound-by verdict fed back
//! into the selection, DaSGD-style — first as a one-shot query, then
//! **live**: a session re-tunes the row collective mid-run from its own
//! critical path (`RetunePolicy::BoundAware`), switching schedules
//! without changing a single weight bit.
//!
//! ```bash
//! cargo run --release --example measured_selector [-- url|news20|rcv1|synthetic] [p]
//! ```

use hybrid_sgd::collectives::{AutoSelector, SelectorSource};
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::calib::measure_collectives;
use hybrid_sgd::costmodel::{CalibProfile, HybridConfig};
use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::partition::Partitioner;
use hybrid_sgd::solvers::{RetunePolicy, SessionBuilder};
use hybrid_sgd::timeline::{CriticalPath, OverlapPolicy};
use hybrid_sgd::util::Table;

fn map_desc(sel: &AutoSelector<'_>, q: usize, max_words: usize) -> String {
    sel.selection_map(q, max_words)
        .iter()
        .map(|(w, a)| format!("{}@{w}", a.name()))
        .collect::<Vec<_>>()
        .join(" -> ")
}

fn main() {
    let mut args = std::env::args().skip(1);
    let spec = args
        .next()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DatasetSpec::UrlLike);
    let p: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(16);

    // 1. Fit this host's per-algorithm curves and attach them to the
    //    charging profile.
    println!("fitting per-algorithm curves on this host (simulated schedule rounds)...");
    let curves = measure_collectives(true);
    let base = CalibProfile::perlmutter();
    let prof = base.clone().with_algo_curves(curves);

    // 2. Round-trip through the TSV schema (what `calibrate --collectives
    //    --save` + `train --profile` do between processes).
    let path = std::env::temp_dir().join("measured_selector_profile.tsv");
    prof.to_tsv(&path).expect("save profile");
    let prof = CalibProfile::from_tsv(&path).expect("reload profile");
    assert!(prof.algo_curves.is_some(), "curves survive the TSV round trip");
    println!("profile round-tripped through {}", path.display());
    println!();

    // 3. The two tuning tables side by side, at the team sizes the quick
    //    calibration actually fit (larger q would just clamp to the q=8
    //    curve and misread as per-q host data).
    let analytic = AutoSelector::new(&base);
    let measured = AutoSelector::new(&prof).with_source(SelectorSource::Measured);
    let mut maps = Table::new(&["team q", "analytic map", "measured map (this host)"]);
    for q in [2usize, 4, 8] {
        maps.row(&[
            q.to_string(),
            map_desc(&analytic, q, 1 << 22),
            map_desc(&measured, q, 1 << 22),
        ]);
    }
    println!("selector crossovers (payloads 1..{} words, fitted team sizes):", 1 << 22);
    println!("{}", maps.render());
    println!();

    // 4. Same run under both sources: trajectories bit-identical, only
    //    the charged books are allowed to move.
    let ds = spec.profile().generate_scaled(0.05, 0x2D5D);
    let mesh = Mesh::factorizations(p)
        .into_iter()
        .find(|m| m.p_r > 1 && m.p_c > 1)
        .unwrap_or(Mesh::new(1, p));
    let s = if mesh.p_c >= 4 { 4 } else { 2 };
    let cfg = HybridConfig::new(mesh, s, 16, 10);
    let run_with = |selector: SelectorSource| {
        SessionBuilder::new(&NativeBackend, &ds, cfg)
            .partitioner(Partitioner::Cyclic)
            .max_bundles(10)
            .eval_every(0)
            .profile(prof.clone())
            .selector(selector)
            .run_to_end()
    };
    let run_a = run_with(SelectorSource::Analytic);
    let run_m = run_with(SelectorSource::Measured);
    assert_eq!(run_a.x, run_m.x, "selector source must never change the trajectory");
    println!(
        "train on {} mesh {}: final weights bit-identical across sources; \
         sim wall {:.4} ms (analytic) vs {:.4} ms (measured crossovers)",
        ds.name,
        mesh,
        run_a.sim_wall * 1e3,
        run_m.sim_wall * 1e3
    );
    println!();

    // 5. Bound-aware selection: ask the timeline analyzer what the
    //    makespan rank is starved on and let that verdict steer the pick
    //    for the row collective's payload.
    let cp = CriticalPath::analyze(&run_m.timeline);
    let rank = cp.makespan_rank();
    let axis = cp.bound_axis(rank);
    let q_row = mesh.p_c.max(2);
    let w_row = cfg.s * cfg.b + cfg.s * cfg.b * (cfg.s * cfg.b + 1) / 2;
    let (plain, _) = measured.pick_cost(q_row, w_row);
    let (aware, _) = measured.pick_bound_aware(q_row, w_row, axis);
    println!(
        "rank {rank} is {}-bound (per the critical path); row collective (q={q_row}, \
         W={w_row}): plain pick {}, bound-aware pick {}",
        axis.name(),
        plain.name(),
        aware.name()
    );
    let overlap_run = SessionBuilder::new(&NativeBackend, &ds, cfg)
        .partitioner(Partitioner::Cyclic)
        .max_bundles(10)
        .eval_every(0)
        .profile(prof.clone())
        .selector(SelectorSource::Measured)
        .overlap(OverlapPolicy::Bundle)
        .run_to_end();
    let cp2 = CriticalPath::analyze(&overlap_run.timeline);
    println!(
        "with --overlap bundle the makespan rank is {}-bound instead \
         (wall {:.4} ms vs {:.4} ms bulk-synchronous)",
        cp2.bound_axis(cp2.makespan_rank()).name(),
        overlap_run.sim_wall * 1e3,
        run_m.sim_wall * 1e3
    );
    println!();

    // 6. The same feedback loop, live: RetunePolicy::BoundAware re-pins
    //    the row collective every k bundles from the session's own
    //    critical path. The config is chosen comm-dominated (big s·b
    //    payload on an 8-wide row team, just below the analytic
    //    Rabenseifner→ring crossover), so the bandwidth-bound verdict
    //    swaps the mid-range default for the shallowest-slope schedule
    //    mid-run — while the trajectory stays bit-identical, selection
    //    moves books only.
    let demo_mesh = Mesh::new(2, 8);
    let demo_cfg = HybridConfig::new(demo_mesh, 4, 50, 10);
    let w_row = {
        let q = demo_cfg.s * demo_cfg.b;
        q + q * (q + 1) / 2
    };
    let plain_pick = AutoSelector::new(&base).pick(demo_mesh.p_c, w_row);
    let demo = |retune: RetunePolicy| {
        SessionBuilder::new(&NativeBackend, &ds, demo_cfg)
            .partitioner(Partitioner::Cyclic)
            .max_bundles(12)
            .eval_every(0)
            .profile(base.clone())
            .retune(retune)
            .build()
    };
    fn drive(
        mut s: hybrid_sgd::solvers::Session<'_>,
    ) -> (hybrid_sgd::solvers::SolverRun, Vec<hybrid_sgd::solvers::RetuneEvent>) {
        while !s.is_done() {
            let _ = s.step_bundle();
        }
        let events = s.retunes().to_vec();
        (s.finish(), events)
    }
    let (fixed_run, _) = drive(demo(RetunePolicy::Off));
    let (tuned_run, events) = drive(demo(RetunePolicy::BoundAware { every: 3 }));
    println!(
        "mid-run re-tuning on mesh {demo_mesh} (row q={}, W_row={w_row} words; \
         plain auto pick: {}):",
        demo_mesh.p_c,
        plain_pick.name()
    );
    for ev in &events {
        println!(
            "  retune @bundle {:>2}: {}-bound critical path -> row collective {} ({})",
            ev.bundle,
            ev.axis.name(),
            ev.algo.name(),
            if ev.switched { "switched" } else { "unchanged" },
        );
    }
    assert_eq!(
        tuned_run.x, fixed_run.x,
        "mid-run retuning must never change the trajectory"
    );
    println!(
        "final weights bit-identical with retuning on/off; \
         sim wall {:.4} ms (retuned) vs {:.4} ms (fixed policy)",
        tuned_run.sim_wall * 1e3,
        fixed_run.sim_wall * 1e3
    );
    let _ = std::fs::remove_file(&path);
}
