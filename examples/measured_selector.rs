//! The measured collective selector, end to end: fit this host's
//! per-algorithm Allreduce curves (the paper's §7.1 microbenchmark
//! methodology applied per schedule), persist them through the TSV
//! profile, diff the measured tuning table against the analytic Hockney
//! envelope, and show that switching the selector source moves charged
//! books only — trajectories stay bit-identical. Finishes with the
//! bound-aware pick: the overlap analyzer's bound-by verdict fed back
//! into the selection, DaSGD-style.
//!
//! ```bash
//! cargo run --release --example measured_selector [-- url|news20|rcv1|synthetic] [p]
//! ```

use hybrid_sgd::collectives::{AutoSelector, SelectorSource};
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::calib::measure_collectives;
use hybrid_sgd::costmodel::{CalibProfile, HybridConfig};
use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::partition::Partitioner;
use hybrid_sgd::solvers::{HybridSolver, RunOpts};
use hybrid_sgd::timeline::{CriticalPath, OverlapPolicy};
use hybrid_sgd::util::Table;

fn map_desc(sel: &AutoSelector<'_>, q: usize, max_words: usize) -> String {
    sel.selection_map(q, max_words)
        .iter()
        .map(|(w, a)| format!("{}@{w}", a.name()))
        .collect::<Vec<_>>()
        .join(" -> ")
}

fn main() {
    let mut args = std::env::args().skip(1);
    let spec = args
        .next()
        .and_then(|s| DatasetSpec::from_name(&s))
        .unwrap_or(DatasetSpec::UrlLike);
    let p: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(16);

    // 1. Fit this host's per-algorithm curves and attach them to the
    //    charging profile.
    println!("fitting per-algorithm curves on this host (simulated schedule rounds)...");
    let curves = measure_collectives(true);
    let base = CalibProfile::perlmutter();
    let prof = base.clone().with_algo_curves(curves);

    // 2. Round-trip through the TSV schema (what `calibrate --collectives
    //    --save` + `train --profile` do between processes).
    let path = std::env::temp_dir().join("measured_selector_profile.tsv");
    prof.to_tsv(&path).expect("save profile");
    let prof = CalibProfile::from_tsv(&path).expect("reload profile");
    assert!(prof.algo_curves.is_some(), "curves survive the TSV round trip");
    println!("profile round-tripped through {}", path.display());
    println!();

    // 3. The two tuning tables side by side, at the team sizes the quick
    //    calibration actually fit (larger q would just clamp to the q=8
    //    curve and misread as per-q host data).
    let analytic = AutoSelector::new(&base);
    let measured = AutoSelector::new(&prof).with_source(SelectorSource::Measured);
    let mut maps = Table::new(&["team q", "analytic map", "measured map (this host)"]);
    for q in [2usize, 4, 8] {
        maps.row(&[
            q.to_string(),
            map_desc(&analytic, q, 1 << 22),
            map_desc(&measured, q, 1 << 22),
        ]);
    }
    println!("selector crossovers (payloads 1..{} words, fitted team sizes):", 1 << 22);
    println!("{}", maps.render());
    println!();

    // 4. Same run under both sources: trajectories bit-identical, only
    //    the charged books are allowed to move.
    let ds = spec.profile().generate_scaled(0.05, 0x2D5D);
    let mesh = Mesh::factorizations(p)
        .into_iter()
        .find(|m| m.p_r > 1 && m.p_c > 1)
        .unwrap_or(Mesh::new(1, p));
    let s = if mesh.p_c >= 4 { 4 } else { 2 };
    let cfg = HybridConfig::new(mesh, s, 16, 10);
    let run_with = |selector: SelectorSource| {
        let opts = RunOpts {
            max_bundles: 10,
            eval_every: 0,
            profile: prof.clone(),
            selector,
            ..Default::default()
        };
        HybridSolver::new(&NativeBackend).run(&ds, cfg, Partitioner::Cyclic, &opts)
    };
    let run_a = run_with(SelectorSource::Analytic);
    let run_m = run_with(SelectorSource::Measured);
    assert_eq!(run_a.x, run_m.x, "selector source must never change the trajectory");
    println!(
        "train on {} mesh {}: final weights bit-identical across sources; \
         sim wall {:.4} ms (analytic) vs {:.4} ms (measured crossovers)",
        ds.name,
        mesh,
        run_a.sim_wall * 1e3,
        run_m.sim_wall * 1e3
    );
    println!();

    // 5. Bound-aware selection: ask the timeline analyzer what the
    //    makespan rank is starved on and let that verdict steer the pick
    //    for the row collective's payload.
    let cp = CriticalPath::analyze(&run_m.timeline);
    let rank = cp.makespan_rank();
    let axis = cp.bound_axis(rank);
    let q_row = mesh.p_c.max(2);
    let w_row = cfg.s * cfg.b + cfg.s * cfg.b * (cfg.s * cfg.b + 1) / 2;
    let (plain, _) = measured.pick_cost(q_row, w_row);
    let (aware, _) = measured.pick_bound_aware(q_row, w_row, axis);
    println!(
        "rank {rank} is {}-bound (per the critical path); row collective (q={q_row}, \
         W={w_row}): plain pick {}, bound-aware pick {}",
        axis.name(),
        plain.name(),
        aware.name()
    );
    let overlap_run = {
        let opts = RunOpts {
            max_bundles: 10,
            eval_every: 0,
            profile: prof.clone(),
            selector: SelectorSource::Measured,
            overlap: OverlapPolicy::Bundle,
            ..Default::default()
        };
        HybridSolver::new(&NativeBackend).run(&ds, cfg, Partitioner::Cyclic, &opts)
    };
    let cp2 = CriticalPath::analyze(&overlap_run.timeline);
    println!(
        "with --overlap bundle the makespan rank is {}-bound instead \
         (wall {:.4} ms vs {:.4} ms bulk-synchronous)",
        cp2.bound_axis(cp2.makespan_rank()).name(),
        overlap_run.sim_wall * 1e3,
        run_m.sim_wall * 1e3
    );
    let _ = std::fs::remove_file(&path);
}
