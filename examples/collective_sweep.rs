//! Map the collective-algorithm tuning table the engine's auto selector
//! induces: for each team size, the payload thresholds where the cheapest
//! Allreduce schedule switches (recursive doubling → Rabenseifner → ring),
//! and for every mesh shape of a paper-scale dataset, which algorithms the
//! row/column collectives actually get and what they cost.
//!
//! ```bash
//! cargo run --release --example collective_sweep [-- url|news20|rcv1|synthetic] [p]
//! ```

use hybrid_sgd::collectives::{charge, AlgoPolicy, Algorithm, AutoSelector};
use hybrid_sgd::costmodel::model::DataShape;
use hybrid_sgd::costmodel::CalibProfile;
use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::experiments::table4;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::util::Table;

fn main() {
    let mut args = std::env::args().skip(1);
    let spec = args
        .next()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DatasetSpec::UrlLike);
    let p: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(256);
    let prof = CalibProfile::perlmutter();

    // 1. The payload crossover map per team size: where the lower envelope
    //    of the three physical schedules switches under the Table 7
    //    profile. The β(q) discontinuity at the node boundary (q = 64)
    //    shows up as a kink in the thresholds.
    let sel = AutoSelector::new(&prof);
    let max_words = 1 << 24;
    let mut cross = Table::new(&["team q", "selection by payload (words)"]);
    for q in [2usize, 4, 8, 16, 32, 64, 128, 256, 1024, 4096, 16384] {
        let segs = sel.selection_map(q, max_words);
        let desc = segs
            .iter()
            .map(|(w, a)| format!("{}@{w}", a.name()))
            .collect::<Vec<_>>()
            .join(" -> ");
        cross.row(&[q.to_string(), desc]);
    }
    println!("collective crossover map (Perlmutter profile, payloads 1..{max_words} words):");
    println!("{}", cross.render());
    println!("(`algo@W` = algorithm cheapest from W words on)");
    println!();

    // 2. What each mesh shape of the chosen dataset actually gets: the row
    //    team's Gram payload is small and latency-sensitive, the column
    //    team's weight shard huge and bandwidth-bound — so one mesh can mix
    //    recursive doubling rows with ring columns, and the aspect ratio
    //    moves both payloads and team sizes through the crossover map.
    let profile = spec.profile();
    let data = DataShape {
        m: profile.paper_m,
        n: profile.paper_n,
        zbar: profile.paper_zbar as f64,
    };
    let mut t = Table::new(&[
        "mesh", "row q", "W_row", "row algo", "row us", "col q", "W_col", "col algo",
        "col us",
    ]);
    for mesh in Mesh::factorizations(p) {
        let cfg = table4::hybrid_cfg(mesh);
        let (w_row, w_col) = table4::bundle_payloads(&cfg, &data);
        let (row_algo, row_cost) = charge(&prof, AlgoPolicy::Auto, mesh.p_c, w_row);
        let (col_algo, col_cost) = charge(&prof, AlgoPolicy::Auto, mesh.p_r, w_col);
        let name = |q: usize, a: Algorithm| if q > 1 { a.name() } else { "-" };
        let us = |t: f64| format!("{:.2}", t * 1e6);
        t.row(&[
            mesh.label(),
            mesh.p_c.to_string(),
            w_row.to_string(),
            name(mesh.p_c, row_algo).to_string(),
            us(row_cost.time),
            mesh.p_r.to_string(),
            w_col.to_string(),
            name(mesh.p_r, col_algo).to_string(),
            us(col_cost.time),
        ]);
    }
    println!("{} at p = {p} (s/b/tau from the Table 4 sweep config):", profile.name);
    println!("{}", t.render());
}
