//! Serve quickstart: the training-service daemon end to end, in process.
//!
//! Stands up a [`Daemon`] on an ephemeral loopback port, submits
//! concurrent jobs through the typed [`Client`], and shows the whole
//! service surface the `serve`/`submit`/`watch` CLI subcommands expose:
//!
//! * **admission planning** — each submit is priced by the cost model
//!   against the live `CalibProfile`: the topology rule shapes the mesh
//!   from the requested `p`, the joint optimum picks `(s, b, overlap)`,
//!   and the reply echoes the plan (knobs + predicted per-epoch
//!   seconds) before a single bundle runs;
//! * **concurrent sessions** — both jobs are admitted onto the rank
//!   budget and step in parallel, one worker thread each;
//! * **streamed telemetry** — `watch` follows a job's per-bundle frames
//!   (loss on the eval cadence, health verdict, simulated wall) live
//!   over TCP;
//! * **prompt cancel** — a long job is canceled mid-run and stops at
//!   the next bundle boundary;
//! * **service metrics** — the daemon keeps an OpenMetrics scrape file
//!   (`serve_quickstart.prom`) with job lifecycle counters and per-job
//!   gauges, validated in CI by `tools/check_metrics.py`;
//! * **graceful drain** — `shutdown` checkpoints in-flight work into
//!   the spool; a daemon restarted on the same spool would resume it
//!   bit-identically (`tests/serve_daemon.rs` proves that equivalence).
//!
//! ```bash
//! cargo run --release --example serve_quickstart -- quick  # CI smoke scale
//! cargo run --release --example serve_quickstart
//! ```
//!
//! The same daemon runs out of process via the CLI:
//!
//! ```bash
//! cargo run --release -- serve --port 7465 --spool /tmp/pallas-spool &
//! cargo run --release -- submit --addr 127.0.0.1:7465 --dataset rcv1 --watch
//! cargo run --release -- status --addr 127.0.0.1:7465
//! cargo run --release -- serve --stop --addr 127.0.0.1:7465
//! ```

use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::serve::{Client, Daemon, DaemonConfig, JobSpec, JobState};
use std::path::PathBuf;

fn main() {
    let quick = std::env::args().any(|a| a == "quick");
    let bundles = if quick { 40 } else { 200 };

    // 1. An in-process daemon: ephemeral port, throwaway spool, scrape
    //    file in the working directory (CI validates it).
    let spool = std::env::temp_dir().join(format!("serve_quickstart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spool);
    let mut cfg = DaemonConfig::local(&spool);
    cfg.metrics_out = Some(PathBuf::from("serve_quickstart.prom"));
    let daemon = Daemon::start(cfg).expect("daemon start");
    println!("daemon on {} (spool {})", daemon.addr(), spool.display());

    // 2. Submit two quick jobs on different datasets. The reply carries
    //    the planner's knob set — nothing here picks s, b, or the mesh.
    let client = Client::new(daemon.addr().to_string());
    let spec = |dataset, seed| JobSpec {
        dataset,
        scale: 0.05,
        p: 2,
        bundles,
        eval_every: 5,
        eta: 0.1,
        tau: 10,
        seed,
        target: None,
        ckpt_every: 8,
        deadline: None,
    };
    let mut ids = Vec::new();
    for (dataset, seed) in [(DatasetSpec::Rcv1Like, 1), (DatasetSpec::SyntheticUniform, 2)] {
        let (row, plan) = client.submit(&spec(dataset, seed)).expect("submit");
        println!(
            "job {} {:>8}  mesh {}  s={} b={}  algo={} overlap={} gram={}  ~{:.4} s/epoch",
            row.id,
            row.state.name(),
            plan.mesh,
            plan.s,
            plan.b,
            plan.algo.name(),
            plan.overlap.name(),
            plan.gram.name(),
            plan.per_epoch_s,
        );
        assert_eq!(row.state, JobState::Running, "both jobs fit the rank budget");
        ids.push(row.id);
    }

    // 3. A third, long job — submitted, then promptly canceled: workers
    //    honour the flag at the next bundle boundary.
    let mut long_spec = spec(DatasetSpec::Rcv1Like, 99);
    long_spec.bundles = 100_000;
    let (long, _) = client.submit(&long_spec).expect("submit long");
    println!("job {} canceled: {}", long.id, client.cancel(long.id).expect("cancel"));

    // 4. Follow the first job's telemetry live over the wire.
    let done = client
        .watch(ids[0], 0, |t| {
            if let Some(loss) = t.loss {
                println!(
                    "  job {} bundle {:>4}  loss {loss:.6}  health {:<10}  sim {:.4}s",
                    t.id, t.bundle, t.health, t.sim_wall
                );
            }
        })
        .expect("watch");
    assert_eq!(done.state, JobState::Done);
    assert_eq!(done.bundles, bundles);

    // 5. Wait for the rest, then print the status board.
    for &id in &ids[1..] {
        client.watch(id, 0, |_| {}).expect("watch");
    }
    client.watch(long.id, 0, |_| {}).expect("watch canceled");
    println!("board:");
    let rows = client.status(None).expect("status");
    for row in &rows {
        println!(
            "  #{} {:>9}  bundles {:>5}  loss {}",
            row.id,
            row.state.name(),
            row.bundles,
            row.loss.map(|l| format!("{l:.6}")).unwrap_or_else(|| "-".into()),
        );
    }
    assert!(rows.iter().filter(|r| r.state == JobState::Done).count() >= 2);
    assert!(rows.iter().any(|r| r.state == JobState::Canceled));

    // 6. Graceful drain; the scrape file survives with the final counts.
    println!("shutdown: {}", client.shutdown().expect("shutdown"));
    let report = daemon.wait();
    assert!(report.forced.is_empty(), "a graceful drain never forces jobs");
    let scrape = std::fs::read_to_string("serve_quickstart.prom").expect("scrape file");
    println!("serve_quickstart.prom (service families):");
    for line in scrape.lines().filter(|l| l.contains("serve_jobs") && !l.starts_with('#')) {
        println!("  {line}");
    }
    let _ = std::fs::remove_dir_all(&spool);
}
