//! Strong-scaling study on the url-like profile (the paper's Fig. 7 left
//! panel as a standalone tool): sweeps p, compares FedAvg, HybridSGD 1×p,
//! and HybridSGD 8×(p/8).
//!
//! ```bash
//! cargo run --release --example url_scaling [-- full]
//! ```

use hybrid_sgd::experiments::{fig7, Effort};

fn main() {
    let effort = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(Effort::Quick);
    println!("{}", fig7::run(effort).render());
    println!("series TSV: results/fig7_strong_scaling.tsv");
}
