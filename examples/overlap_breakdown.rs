//! Per-phase critical-path breakdown of compute/communication overlap:
//! sweep the mesh factorizations of `p`, run each with `--overlap off`
//! and `--overlap bundle`, and show where the simulated makespan goes —
//! charged, wait, and hidden seconds per phase from the timeline
//! analyzer, plus which phase each configuration is actually bound by.
//!
//! ```bash
//! cargo run --release --example overlap_breakdown [-- url|news20|rcv1|synthetic] [p] [scale]
//! ```

use hybrid_sgd::comm::OverlapPolicy;
use hybrid_sgd::compute::NativeBackend;
use hybrid_sgd::costmodel::{CalibProfile, HybridConfig};
use hybrid_sgd::data::{Dataset, DatasetSpec};
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::metrics::Phase;
use hybrid_sgd::partition::Partitioner;
use hybrid_sgd::solvers::{SessionBuilder, SolverRun};
use hybrid_sgd::timeline::CriticalPath;
use hybrid_sgd::util::Table;

fn ms(t: f64) -> String {
    format!("{:.3}", t * 1e3)
}

fn run(ds: &Dataset, mesh: Mesh, overlap: OverlapPolicy) -> SolverRun {
    let cfg = if mesh.p_c == 1 {
        HybridConfig::new(mesh, 1, 32, 10)
    } else {
        HybridConfig::new(mesh, 4, 32, 10)
    };
    SessionBuilder::new(&NativeBackend, ds, cfg)
        .partitioner(Partitioner::Cyclic)
        .max_bundles(20)
        .eval_every(0)
        .overlap(overlap)
        // Scatter Gram pinned: the breakdown compares charged books
        // across overlap policies, and a fixed kernel keeps the host-side
        // timing noise out of the measured walls (charged books are
        // gram-invariant either way).
        .gram(hybrid_sgd::sparse::GramStrategy::Scatter)
        .profile(CalibProfile::perlmutter_contended())
        .run_to_end()
}

fn main() {
    let mut args = std::env::args().skip(1);
    let spec = args
        .next()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DatasetSpec::UrlLike);
    let p: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(32);
    let scale: f64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let ds = spec.profile().generate_scaled(scale, 0x2D5D);
    println!(
        "{} at scale {scale} (m={} n={} zbar={:.0}), p = {p}, 20 bundles, s=4 b=32 tau=10:",
        ds.name,
        ds.m(),
        ds.n(),
        ds.zbar()
    );
    println!();

    // 1. Mesh sweep: how much of the row reduce each aspect ratio can
    //    hide behind the next bundle's SpMV, and what each shape's
    //    makespan is bound by once it does.
    let mut sweep = Table::new(&[
        "mesh",
        "off wall (ms)",
        "bundle wall (ms)",
        "hidden (ms)",
        "gain",
        "bound by",
    ]);
    let mut best: Option<(f64, Mesh, SolverRun)> = None;
    for mesh in Mesh::factorizations(p) {
        let off = run(&ds, mesh, OverlapPolicy::Off);
        let bun = run(&ds, mesh, OverlapPolicy::Bundle);
        let cp = CriticalPath::analyze(&bun.timeline);
        let hidden = bun.book.mean_hidden(Phase::SstepComm);
        let gain = if bun.sim_wall > 0.0 { off.sim_wall / bun.sim_wall } else { 1.0 };
        sweep.row(&[
            mesh.label(),
            ms(off.sim_wall),
            ms(bun.sim_wall),
            ms(hidden),
            format!("{gain:.2}x"),
            cp.makespan_bound_by().name().to_string(),
        ]);
        let replace = best.as_ref().map(|(g, _, _)| gain > *g).unwrap_or(true);
        if replace {
            best = Some((gain, mesh, bun));
        }
    }
    println!("overlap gain per mesh shape (--overlap off vs bundle):");
    println!("{}", sweep.render());
    println!("(hidden = row-reduce transfer charged behind compute, mean/rank)");
    println!();

    // 2. The per-phase critical path of the best-gain shape, straight
    //    from the timeline analyzer: charged/wait/hidden per phase and
    //    the rank the makespan actually sits on.
    let (gain, mesh, bun) = best.expect("at least one mesh factorization");
    let cp = CriticalPath::analyze(&bun.timeline);
    let mut phases = Table::new(&[
        "phase",
        "charged (ms)",
        "wait (ms)",
        "hidden (ms)",
        "max charged (ms)",
    ]);
    for (ph, line) in cp.rows() {
        phases.row(&[
            ph.name().to_string(),
            ms(line.charged),
            ms(line.wait),
            ms(line.hidden),
            ms(line.charged_max),
        ]);
    }
    println!("per-phase critical path at mesh {} (best gain {gain:.2}x, overlap=bundle):", mesh);
    println!("{}", phases.render());
    println!(
        "makespan {:.3} ms on rank {} — bound by {}",
        cp.makespan() * 1e3,
        cp.makespan_rank(),
        cp.makespan_bound_by().name()
    );
}
