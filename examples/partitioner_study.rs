//! Irregularity-aware partitioning study (paper §7.3 as a standalone
//! tool): for a chosen dataset profile, surveys the three partitioners'
//! κ / cache-footprint trade-off, runs the two-objective selector, shows
//! the refined predictor's ranking, and measures per-iteration truth.
//!
//! ```bash
//! cargo run --release --example partitioner_study [-- url|news20|rcv1]
//! ```

use hybrid_sgd::costmodel::model::DataShape;
use hybrid_sgd::costmodel::predictor::{self, PartitionShape, PredictorKnobs};
use hybrid_sgd::costmodel::{CalibProfile, HybridConfig};
use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::experiments::fixtures;
use hybrid_sgd::experiments::Effort;
use hybrid_sgd::mesh::Mesh;
use hybrid_sgd::partition::stats::{select_two_objective, L_CAP_BYTES};
use hybrid_sgd::partition::{ColPartition, Partitioner};
use hybrid_sgd::util::table::fmt_bytes;
use hybrid_sgd::util::Table;

fn main() {
    let spec = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DatasetSpec::UrlLike);
    let effort = Effort::Quick;
    let ds = fixtures::dataset(spec, effort);
    let p_c = 64.min(ds.n() / 4).max(2);
    let mesh = Mesh::new(4, p_c);
    let cfg = HybridConfig::new(mesh, 4, 32, 10);
    println!(
        "dataset {} (m={} n={} zbar={:.0}), mesh {}, L_cap = {}",
        ds.name,
        ds.m(),
        ds.n(),
        ds.zbar(),
        mesh,
        fmt_bytes(L_CAP_BYTES as f64)
    );

    let profile = CalibProfile::perlmutter();
    let knobs = PredictorKnobs::default();
    let data = DataShape { m: ds.m(), n: ds.n(), zbar: ds.zbar() };

    let mut t = Table::new(&[
        "partitioner",
        "kappa",
        "max n_local",
        "max slab",
        "fits L2",
        "predicted ms/iter",
        "measured ms/iter",
    ]);
    for policy in Partitioner::all() {
        let part = ColPartition::build(&ds.a, p_c, policy);
        let shape = PartitionShape::of(&part);
        let pred = predictor::predict(&cfg, &data, &shape, &profile, &knobs).total();
        let meas = fixtures::measure(&ds, cfg, policy, 12).per_iter;
        t.row(&[
            policy.name().to_string(),
            format!("{:.2}", part.kappa()),
            part.max_n_local().to_string(),
            fmt_bytes(part.max_weight_bytes() as f64),
            (part.max_weight_bytes() <= L_CAP_BYTES).to_string(),
            format!("{:.4}", pred * 1e3),
            format!("{:.4}", meas * 1e3),
        ]);
    }
    println!("{}", t.render());
    println!(
        "two-objective selection (min kappa s.t. slab <= L_cap): {}",
        select_two_objective(&ds.a, p_c, L_CAP_BYTES).name()
    );
}
