//! Mesh-transition sweep (the paper's Fig. 5 as a standalone tool): walk
//! every factorization p_r × p_c = p from the 1D s-step corner to the
//! FedAvg corner and watch the per-iteration time trace the solver-family
//! continuum; compare with the topology rule's pick.
//!
//! ```bash
//! cargo run --release --example mesh_sweep [-- url|news20|rcv1] [p]
//! ```

use hybrid_sgd::costmodel::topology;
use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::experiments::{fig5, fixtures, Effort};
use hybrid_sgd::util::Table;

fn main() {
    let mut args = std::env::args().skip(1);
    let spec = args
        .next()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DatasetSpec::UrlLike);
    let p: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(256);
    let effort = Effort::Quick;

    let ds_n = fixtures::dataset(spec, effort).n();
    let rule = topology::mesh_rule(ds_n, p, 64, 1 << 20);
    let series = fig5::sweep(spec, p, effort);
    let min = series
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("nonempty sweep")
        .0;

    let mut t = Table::new(&["p_r", "p_c", "ms/iter", ""]);
    for (p_r, per_iter) in &series {
        let mut mark = String::new();
        if *p_r == 1 {
            mark.push_str("1D s-step corner ");
        }
        if *p_r == p {
            mark.push_str("FedAvg corner ");
        }
        if *p_r == min {
            mark.push_str("<-- min ");
        }
        if *p_r == rule.p_r {
            mark.push_str("<-- rule (Eq. 7)");
        }
        t.row(&[
            p_r.to_string(),
            (p / p_r).to_string(),
            format!("{:.4}", per_iter * 1e3),
            mark.trim().to_string(),
        ]);
    }
    println!("dataset {} at p = {p}:\n{}", spec.profile().name, t.render());
}
