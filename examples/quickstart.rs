//! Quickstart: the end-to-end driver proving all three layers compose.
//!
//! Loads the AOT-compiled JAX+Pallas artifacts through PJRT (L1/L2),
//! partitions a real generated url-like dataset over a 2D mesh, and runs
//! HybridSGD through the distributed engine (L3) — via the **session
//! API**: a [`SessionBuilder`] configures the run, `step_bundle()` drives
//! it one outer bundle at a time (printing the loss curve as the evals
//! arrive), and `finish()` assembles the result. Then repeats with FedAvg
//! for contrast. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! The hybrid run demonstrates the observability layer: two trace sinks
//! stream every span to `quickstart_trace.jsonl` (line-oriented, for
//! scripts) and `quickstart_trace.json` (Chrome `trace_event` — open it
//! in `chrome://tracing` or <https://ui.perfetto.dev>, one track per
//! rank), a metrics sink keeps `quickstart_metrics.prom` — an
//! OpenMetrics scrape file with the live loss, health verdict, per-phase
//! model-drift gauges, and overlap efficiency — current at every bundle
//! boundary, and the run ends with the versioned `obs::summary` TSV
//! block.
//!
//! The run executes on the engine's **execution backend** seam: `sim`
//! (default) walks the ranks on the host thread with fully simulated
//! clocks; `threads` runs each rank as a real OS thread and every
//! collective as a barrier-synchronized shared-memory reduction — values
//! bit-identical to sim, with measured per-phase wall seconds recorded
//! alongside the charged books (printed at the end, and scored by the
//! `wall_*` drift gauges in the summary).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! cargo run --release --example quickstart -- quick   # CI smoke scale
//! cargo run --release --example quickstart -- quick threads  # real ranks
//! HYBRID_SGD_BACKEND=threads cargo run --release --example quickstart
//! ```
//!
//! The same scrape file comes out of the CLI with `train --metrics-out`;
//! point a Prometheus at it with the node-exporter textfile pattern:
//!
//! ```bash
//! cargo run --release -- train --dataset url --p 16 \
//!     --metrics-out /var/lib/node_exporter/textfile/hybridsgd.prom
//! # prometheus.yml: the node_exporter textfile collector re-reads the
//! # file each scrape, so `hybridsgd_loss`, `hybridsgd_health`, and the
//! # `hybridsgd_model_drift{series=...}` gauges chart live in Grafana.
//! ```

use hybrid_sgd::comm::ExecBackend;
use hybrid_sgd::compute::{ComputeBackend, NativeBackend};
use hybrid_sgd::metrics::Phase;
use hybrid_sgd::costmodel::{topology, CalibProfile, HybridConfig};
use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::obs::{JsonlSink, PerfettoSink, PrometheusSink, RunSummary};
use hybrid_sgd::partition::stats::{select_two_objective, L_CAP_BYTES};
use hybrid_sgd::runtime::XlaBackend;
use hybrid_sgd::solvers::{SessionBuilder, SolverKind};
use hybrid_sgd::sparse::GramStrategy;
use std::time::Instant;

fn main() {
    let quick = std::env::args().any(|a| a == "quick");
    let exec = if std::env::args().any(|a| a == "threads") {
        ExecBackend::Threads
    } else {
        ExecBackend::from_env()
    };
    let (scale, p, max_bundles) = if quick { (0.05, 16, 150) } else { (0.12, 64, 600) };

    // 1. A real small workload: the url-like profile (sparse, huge-n,
    //    column-skewed — HybridSGD's home regime).
    let ds = DatasetSpec::UrlLike.profile().generate_scaled(scale, 42);
    println!(
        "dataset {}: m={} n={} zbar={:.0} nnz={}",
        ds.name,
        ds.m(),
        ds.n(),
        ds.zbar(),
        ds.a.nnz()
    );

    // 2. Model-driven configuration: topology rule + two-objective
    //    partitioner selection (no hand tuning).
    let mesh = topology::mesh_rule(ds.n(), p, 64, 1 << 20);
    let policy = select_two_objective(&ds.a, mesh.p_c, L_CAP_BYTES);
    println!("topology rule picked mesh {mesh}; two-objective partitioner: {}", policy.name());

    // 3. The XLA backend: AOT artifacts, compiled once, Python nowhere.
    let xla;
    let backend: &dyn ComputeBackend = match XlaBackend::load_default() {
        Ok(be) => {
            println!("XLA backend up: {} artifacts", be.artifact_names().len());
            xla = be;
            &xla
        }
        Err(e) => {
            println!("artifacts not built ({e:#}); using native backend");
            &NativeBackend
        }
    };

    // 4. Train to a target loss, one bundle at a time through the session
    //    API (the builder absorbs what used to be a RunOpts struct).
    let cfg = HybridConfig::new(mesh, 4, 32, 10);
    println!("execution backend: {} (select with `-- threads` or HYBRID_SGD_BACKEND)", exec.name());
    let session = |cfg, policy| {
        SessionBuilder::new(backend, &ds, cfg)
            .partitioner(policy)
            // Execution backend seam: `threads` turns every rank into an
            // OS thread and every collective into a real shared-memory
            // reduction; the trajectory stays bit-identical to `sim`.
            .backend(exec)
            .eta(0.5)
            .max_bundles(max_bundles)
            .eval_every(5)
            .target_loss(Some(0.55))
            // Bundle Gram strategy: `Auto` (the default, spelled out
            // here) resolves merge vs scatter per rank block from its
            // measured row density — host wall time only, values are
            // bit-identical across strategies.
            .gram(GramStrategy::Auto)
            .profile(CalibProfile::perlmutter())
    };
    let wall0 = Instant::now();
    // Observability: stream the span trace in both formats while the run
    // goes (attaching a sink forces event-log recording on; charging and
    // the trajectory are bit-identical with tracing on or off).
    let mut builder = session(cfg, policy);
    match JsonlSink::create("quickstart_trace.jsonl") {
        Ok(sink) => builder = builder.trace_sink(Box::new(sink)),
        Err(e) => println!("(jsonl trace unavailable: {e})"),
    }
    match PerfettoSink::create("quickstart_trace.json") {
        Ok(sink) => builder = builder.trace_sink(Box::new(sink)),
        Err(e) => println!("(perfetto trace unavailable: {e})"),
    }
    // Metrics: a live OpenMetrics scrape file, rewritten at every bundle
    // boundary (loss, health verdict, per-phase model drift, overlap
    // efficiency). Observation-only, like the traces.
    match PrometheusSink::create("quickstart_metrics.prom") {
        Ok(sink) => builder = builder.metrics_sink(Box::new(sink)),
        Err(e) => println!("(metrics export unavailable: {e})"),
    }
    let mut hybrid = builder.build();
    println!("\nloss curve (bundle, simulated s, loss):");
    while !hybrid.is_done() {
        let Some(report) = hybrid.step_bundle() else { break };
        if let Some(pt) = report.eval {
            println!("  {:>5}  {:>9.4}  {:.5}", pt.bundles, pt.sim_time, pt.loss);
        }
    }
    let run = hybrid.finish();
    let wall = wall0.elapsed().as_secs_f64();

    let fmt_loss = |l: Option<f64>| l.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into());
    println!(
        "\nHybridSGD: {} iters, {:.4} ms/iter simulated, final loss {}, accuracy {:.3}, host wall {:.1}s",
        run.inner_iters,
        run.per_iter() * 1e3,
        fmt_loss(run.final_loss()),
        ds.accuracy(&run.x),
        wall
    );
    if let Some(t) = run.time_to_target {
        println!("time-to-target 0.55: {t:.4} simulated s");
    }
    println!("health: {}", run.health.name());
    if exec == ExecBackend::Threads {
        let phases: Vec<Phase> =
            Phase::all().into_iter().filter(|ph| ph.in_algorithm_total()).collect();
        let charged: f64 = phases.iter().map(|&ph| run.book.mean_charged(ph)).sum();
        let measured: f64 = phases.iter().map(|&ph| run.measured.mean_charged(ph)).sum();
        println!(
            "threads backend: {measured:.4} s measured wall vs {charged:.4} s charged \
             (mean/rank; per-phase `measured` rows in the summary below)"
        );
    }
    for d in run.drift.iter().filter(|d| d.flagged) {
        println!(
            "model drift flagged: {} (ewma relative error {:.3})",
            d.key.name(),
            d.ewma
        );
    }
    println!(
        "\ntraces written: quickstart_trace.jsonl (one JSON object per span) and \
         quickstart_trace.json (open in chrome://tracing or ui.perfetto.dev — \
         one track per rank); metrics in quickstart_metrics.prom (OpenMetrics)"
    );
    println!("\nrun summary (obs::summary schema, kind key a b c d):");
    print!("{}", RunSummary::from_run(&run).render());

    // 5. FedAvg contrast at the same rank count (run_to_end: the
    //    compatibility one-liner over the same session machinery).
    let fed = session(
        SolverKind::FedAvg.config(p, None, 1, 32, 10),
        hybrid_sgd::partition::Partitioner::Rows,
    )
    .run_to_end();
    println!(
        "FedAvg:    {} iters, {:.4} ms/iter simulated, final loss {}{}",
        fed.inner_iters,
        fed.per_iter() * 1e3,
        fmt_loss(fed.final_loss()),
        fed.time_to_target
            .map(|t| format!(", time-to-target {t:.4} s"))
            .unwrap_or_else(|| ", target not reached in budget".into())
    );
    match (run.time_to_target, fed.time_to_target) {
        (Some(h), Some(f)) => println!("\nHybridSGD speedup to target: {:.1}x", f / h),
        _ => println!("\n(one of the solvers did not reach the target in budget)"),
    }
}
