//! Time-to-target-loss race (the paper's Table 11 / Fig. 6 as a
//! standalone tool): FedAvg vs HybridSGD on one dataset profile, with the
//! target calibrated to the slower solver's terminal loss.
//!
//! ```bash
//! cargo run --release --example convergence_race [-- url|news20|rcv1|epsilon]
//! ```

use hybrid_sgd::data::DatasetSpec;
use hybrid_sgd::experiments::{fixtures, table11, Effort};

fn main() {
    let spec = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DatasetSpec::UrlLike);
    let effort = Effort::Quick;
    let ds = fixtures::dataset(spec, effort);
    let sizes = vec![(spec, ds.n())];
    let matchup = table11::matchups(&sizes)
        .into_iter()
        .find(|m| m.spec == spec)
        .expect("matchup defined for every registry dataset");

    println!(
        "racing FedAvg(p={}) vs HybridSGD({}, {}) on {} (m={} n={})",
        matchup.fed_p,
        matchup.hyb_mesh,
        matchup.policy.name(),
        ds.name,
        ds.m(),
        ds.n()
    );
    let race = table11::race(&ds, &matchup, 0.1, 120);
    println!("calibrated target loss: {:.5}\n", race.target);
    println!("trace (simulated s, loss) — fedavg:");
    for t in race.fed_run.trace.iter().step_by(4) {
        println!("  {:>9.4}  {:.5}", t.sim_time, t.loss);
    }
    println!("trace — hybrid:");
    for t in race.hyb_run.trace.iter().step_by(4) {
        println!("  {:>9.4}  {:.5}", t.sim_time, t.loss);
    }
    println!(
        "\ntime-to-target: fedavg {} s, hybrid {} s",
        race.fed_time.map(|t| format!("{t:.4}")).unwrap_or("-".into()),
        race.hyb_time.map(|t| format!("{t:.4}")).unwrap_or("-".into()),
    );
    if let Some(sp) = race.speedup() {
        println!("HybridSGD speedup: {sp:.1}x (paper url: 53x, rcv1: 1.11x, epsilon: 0.44x)");
    }
}
